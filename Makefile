.PHONY: test quick slow verify serve-smoke gateway-smoke chaos-smoke perf-smoke gateway

# full tier-1 suite (same command ROADMAP.md documents)
test:
	PYTHONPATH=src python -m pytest -x -q

# quick loop: everything except the multi-minute subprocess tests
quick:
	python -m pytest -q -m "not slow"

slow:
	python -m pytest -q -m slow

# quick suite + the 8-device GRASP exchange equivalence check + serve smoke
verify:
	./scripts/verify.sh

# end-to-end repro.serve check on a zipf stream (non-tier-1): GRASP cache
# must beat the unpinned baselines and shed-load must bound p99; emits
# BENCH_serve.json
serve-smoke:
	PYTHONPATH=src python -m benchmarks.serve_smoke --out BENCH_serve.json

# loopback load test of the repro.gateway RPC front-end (non-tier-1):
# closed-loop hit rate over real sockets + 2x-overload open loop with the
# shed-load tail bound and 503-retry recovery; emits BENCH_gateway.json
gateway-smoke:
	PYTHONPATH=src python -m benchmarks.gateway_smoke --out BENCH_gateway.json

# seeded fault-injection run of the gateway stack (non-tier-1): request
# conservation under crashes/resets/latency spikes, supervisor restarts ==
# injected pump deaths, breaker-bounded 500 tail, same-seed injection-log
# determinism, and warm-restart snapshot hit-rate recovery; emits
# BENCH_chaos.json
chaos-smoke:
	PYTHONPATH=src python -m benchmarks.chaos_smoke --out BENCH_chaos.json

# tracked perf baseline (non-tier-1): vectorized cache lookup rows/s vs the
# retained reference loop (>=3x floor at batch 256 / zipf 1.1, bit-identical
# outputs + counters), pipelined vs sequential GRASP dist step (bit-exact
# loss+params on the 8-device mesh), and the hot_gather kernel microbench;
# emits BENCH_perf.json
perf-smoke:
	PYTHONPATH=src python -m benchmarks.perf_smoke --out BENCH_perf.json

# launch the gateway for manual poking (recsys engine on :8077):
#   curl -s -XPOST localhost:8077/v1/score -d '{"hist":[1,2,3],"candidates":[4,5]}'
gateway:
	PYTHONPATH=src python -m repro.launch.serve --engine recsys --gateway 127.0.0.1:8077
