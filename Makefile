.PHONY: test quick slow verify

# full tier-1 suite (same command ROADMAP.md documents)
test:
	PYTHONPATH=src python -m pytest -x -q

# quick loop: everything except the multi-minute subprocess tests
quick:
	python -m pytest -q -m "not slow"

slow:
	python -m pytest -q -m slow

# quick suite + the 8-device GRASP exchange equivalence check
verify:
	./scripts/verify.sh
