.PHONY: test quick slow verify serve-smoke

# full tier-1 suite (same command ROADMAP.md documents)
test:
	PYTHONPATH=src python -m pytest -x -q

# quick loop: everything except the multi-minute subprocess tests
quick:
	python -m pytest -q -m "not slow"

slow:
	python -m pytest -q -m slow

# quick suite + the 8-device GRASP exchange equivalence check + serve smoke
verify:
	./scripts/verify.sh

# end-to-end repro.serve check on a zipf stream (non-tier-1): GRASP cache
# must beat the unpinned baselines and shed-load must bound p99; emits
# BENCH_serve.json
serve-smoke:
	PYTHONPATH=src python -m benchmarks.serve_smoke --out BENCH_serve.json
