"""`make chaos-smoke` — seeded fault-injection run of the gateway stack.

`gateway_smoke` proves the serving stack under *healthy* conditions; this
benchmark proves the resilience layer under injected faults, in four
phases over real threads and loopback sockets:

1. **fault phase** — the recsys engine is wrapped in ``ChaosEngine``
   (seeded forward errors, latency spikes, ``next_batch`` pump crashes)
   behind a supervised pump, and driven by a ``ChaosClient`` that injects
   post-execution connection resets (the double-execution hazard).
   Asserts *conservation*: every request reaches exactly one terminal
   outcome, zero hangs, server-side admitted == completed+shed+failed;
   the supervisor restarted **every** injected pump crash; client-visible
   500s stay bounded by the injected forward-error count; and at least
   one reset retry was answered from the idempotency dedupe instead of
   re-executing.
2. **breaker phase** — the engine is flipped to fail persistently;
   with ``failure_threshold`` k, exactly k requests pay a 500 and every
   subsequent request sheds instantly with 503 (the engine's forward is
   *not* called — the 500 tail is bounded); after the fault clears and
   the cooldown elapses, a half-open probe closes the breaker again.
3. **determinism** — the fault phase's schedule is replayed end-to-end
   twice with the same seed; the two ``InjectionLog``s (and the outcome
   tallies) must be identical.
4. **warm restart** — a gateway with ``snapshot_dir`` warms its GRASP
   cache on a zipf stream, measures a closed-loop probe hit rate, drains
   (snapshot saved), and a *fresh* engine+gateway restores the snapshot:
   the same probe's hit rate must be within 1 point of the pre-restart
   baseline, while a cold-started control shows the re-paid misses.

Emits all four phases plus a verdict to ``BENCH_chaos.json``.

    PYTHONPATH=src python -m benchmarks.chaos_smoke [--out BENCH_chaos.json]

Non-tier-1: wired into scripts/verify.sh after gateway_smoke. Wall-clock
is bounded: every join carries a timeout and all load is finite.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import threading
import time

import numpy as np

from benchmarks.gateway_smoke import CANDIDATES, _make_engine, _payloads
from repro.chaos import ChaosClient, ChaosEngine, FaultSchedule, FaultSpec
from repro.gateway import (
    EnginePump,
    GatewayClient,
    GatewayError,
    GatewayServer,
    Unavailable,
)
from repro.serve.scheduler import SchedulerConfig

JOIN_TIMEOUT_S = 120.0

FAULT_SPEC = FaultSpec(
    seed=42,
    forward_error_rate=0.06,
    latency_spike_rate=0.05,
    latency_spike_s=0.02,
    pump_crash_rate=0.04,
    conn_reset_rate=0.08,
)

SUPERVISOR_CONFIG = dict(
    check_interval_s=0.005,
    wedge_timeout_s=10.0,          # >> the injected 20ms spikes
    backoff_s=0.01,
    backoff_cap_s=0.05,
    crash_loop_threshold=10_000,   # sustained injection must keep restarting
)


def _run_workload(requests: int, workers: int, spec: FaultSpec,
                  breaker: bool):
    """One supervised chaos run: returns (outcomes, schedule, server-side
    snapshot, supervisor stats, client stats, dedupe stats)."""
    sched = SchedulerConfig(max_batch=8, max_queue=64)
    engine = _make_engine(pace_s=0.0, sched=sched)
    schedule = FaultSchedule(spec)
    chaos = ChaosEngine(engine, schedule)
    server = GatewayServer(
        {"score": EnginePump(chaos, "score")},
        supervisor_config=SUPERVISOR_CONFIG,
        breaker=breaker,
        breaker_config={"failure_threshold": 3, "cooldown_s": 0.1},
    ).start()
    client = ChaosClient(server.url, schedule, reset_mode="post",
                         timeout_s=20.0, retries=8, backoff_s=0.02,
                         backoff_cap_s=0.2)
    payloads = _payloads(engine.cfg, requests, seed=3)
    outcomes = {"done": 0, "failed": 0, "rejected": 0, "shed": 0,
                "unavailable": 0, "timeout": 0, "error": 0}
    order = [None] * requests
    out_lock = threading.Lock()
    it = iter(range(requests))

    def worker():
        while True:
            with out_lock:
                i = next(it, None)
            if i is None:
                return
            try:
                s = client.score(payloads[i]["hist"],
                                 payloads[i]["candidates"], timeout_s=20.0)
                assert s.shape == (CANDIDATES,) and np.isfinite(s).all()
                kind = "done"
            except GatewayError as e:
                kind = e.kind if e.kind in outcomes else "error"
            except Exception:  # noqa: BLE001 — tally, never die silently
                kind = "error"
            with out_lock:
                outcomes[kind] += 1
                order[i] = kind

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(workers)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(JOIN_TIMEOUT_S)
    hung = sum(t.is_alive() for t in threads)
    sup = server.supervisors["score"]
    sup_stats = sup.stats()
    dedupe_stats = server.dedupe.stats()
    breaker_stats = (server.breakers["score"].stats() if breaker else None)
    server.stop()
    snap = engine.metrics.snapshot()
    assert hung == 0, f"chaos: {hung} worker(s) hung"
    return {
        "outcomes": outcomes,
        "order": order,
        "injections": schedule.log.summary(),
        "log": schedule.log.entries(),
        "supervisor": sup_stats,
        "client": dict(client.stats),
        "dedupe": dedupe_stats,
        "breaker": breaker_stats,
        "snapshot": snap,
    }


# ---------------------------------------------------------------------------
# phase 1: conservation + supervision under the full fault schedule
# ---------------------------------------------------------------------------
def fault_phase(requests: int = 192, workers: int = 4):
    r = _run_workload(requests, workers, FAULT_SPEC, breaker=True)
    o, inj, c = r["outcomes"], r["injections"], r["snapshot"]["counters"]

    # -- conservation: every request reached exactly one terminal status --
    assert sum(o.values()) == requests, o
    assert o["timeout"] == 0 and o["error"] == 0, o
    # server side: everything admitted was completed, shed, or failed
    assert c["admitted"] == (c.get("completed", 0) + c.get("shed", 0)
                             + c.get("failed", 0)), c

    # -- supervision: every injected pump crash was restarted -------------
    crashes = inj.get("pump_crash", 0)
    assert crashes > 0, f"schedule injected no pump crashes: {inj}"
    assert r["supervisor"]["restarts"] == crashes, (r["supervisor"], inj)
    assert r["supervisor"]["wedges"] == 0, r["supervisor"]

    # -- fault blast radius stays bounded ---------------------------------
    fwd_errors = inj.get("forward_error", 0)
    assert fwd_errors > 0, f"schedule injected no forward errors: {inj}"
    # one injected forward error fails one batch of at most `workers`
    # in-flight requests (closed loop); the breaker can only shrink this
    assert o["failed"] <= workers * fwd_errors, (o, inj)
    assert o["done"] > 0.5 * requests, o   # chaos must not starve serving

    # -- reset retries were deduped, not double-executed ------------------
    resets = inj.get("conn_reset", 0)
    assert resets > 0, f"schedule injected no connection resets: {inj}"
    assert r["client"]["retries_conn"] > 0, r["client"]
    assert r["dedupe"]["replays"] > 0, (
        f"no reset retry was answered from the idempotency dedupe: "
        f"{r['dedupe']} (resets={resets})")
    return r


# ---------------------------------------------------------------------------
# phase 2: the breaker bounds the 500 tail of a persistent fault
# ---------------------------------------------------------------------------
class _Breakable:
    """Engine wrapper with a persistent-failure switch (not schedule-driven:
    the breaker phase needs a fault that does NOT go away on its own)."""

    def __init__(self, engine) -> None:
        self._engine = engine
        self.batcher = engine.batcher
        self.failing = False
        self.forwards = 0

    def forward(self, payloads):
        self.forwards += 1
        if self.failing:
            raise RuntimeError("persistent engine fault (chaos)")
        return self._engine.forward(payloads)

    def __getattr__(self, name):
        return getattr(self._engine, name)


def breaker_phase(requests: int = 10, threshold: int = 3,
                  cooldown_s: float = 0.2):
    sched = SchedulerConfig(max_batch=8, max_queue=64)
    engine = _Breakable(_make_engine(pace_s=0.0, sched=sched))
    server = GatewayServer(
        {"score": EnginePump(engine, "score")},
        breaker_config={"failure_threshold": threshold,
                        "cooldown_s": cooldown_s},
    ).start()
    client = GatewayClient(server.url, timeout_s=20.0, retries=0)
    payloads = _payloads(engine.cfg, requests + 1, seed=5)

    engine.failing = True
    tail = []
    for i in range(requests):
        try:
            client.score(payloads[i]["hist"], payloads[i]["candidates"],
                         timeout_s=20.0)
            tail.append("done")
        except GatewayError as e:
            tail.append(e.kind)
    forwards_during_fault = engine.forwards

    # exactly `threshold` requests paid a 500; the rest shed instantly
    # with 503 and never touched the engine — the tail is bounded
    assert tail == ["failed"] * threshold + ["unavailable"] * (
        requests - threshold), tail
    assert forwards_during_fault == threshold, forwards_during_fault
    stats_open = server.breakers["score"].stats()
    assert stats_open["state"] == "open" and stats_open["opened"] == 1

    # fault clears; after the cooldown the half-open probe closes it again
    engine.failing = False
    time.sleep(cooldown_s + 0.05)
    s = client.score(payloads[requests]["hist"],
                     payloads[requests]["candidates"], timeout_s=20.0)
    assert np.isfinite(s).all()
    stats_closed = server.breakers["score"].stats()
    assert stats_closed["state"] == "closed", stats_closed
    server.stop()
    return {"tail": tail, "forwards_during_fault": forwards_during_fault,
            "threshold": threshold, "breaker_open": stats_open,
            "breaker_closed": stats_closed}


# ---------------------------------------------------------------------------
# phase 3: same seed => identical injection logs (and identical outcomes)
# ---------------------------------------------------------------------------
def determinism_phase(requests: int = 64):
    spec = FaultSpec(seed=7, forward_error_rate=0.08, latency_spike_rate=0.05,
                     latency_spike_s=0.005, pump_crash_rate=0.06,
                     conn_reset_rate=0.10)
    # sequential (1 worker) + no breaker: the request->fault mapping is then
    # a pure function of the seed, so the whole run replays bit-identically
    runs = [_run_workload(requests, workers=1, spec=spec, breaker=False)
            for _ in range(2)]
    log_a, log_b = runs[0]["log"], runs[1]["log"]
    assert len(log_a) > 0, "determinism schedule fired nothing"
    assert log_a == log_b, (
        f"same-seed runs diverged: {len(log_a)} vs {len(log_b)} events; "
        f"first diff {next((x for x in zip(log_a, log_b) if x[0] != x[1]), None)}")
    assert runs[0]["order"] == runs[1]["order"], "outcome sequences diverged"
    return {"events": len(log_a), "injections": runs[0]["injections"],
            "outcomes": runs[0]["outcomes"], "identical": True}


# ---------------------------------------------------------------------------
# phase 4: warm-restart snapshot recovers the pre-crash hit rate
# ---------------------------------------------------------------------------
def _drive(client, payloads, timeout_s=20.0):
    for p in payloads:
        client.score(p["hist"], p["candidates"], timeout_s=timeout_s)


def _probe_hit_rate(engine, client, payloads):
    """Closed-loop hit rate over `payloads`, from counter deltas."""
    def refs(c):
        return (c.get("hot_hits", 0), c.get("cold_hits", 0), c.get("misses", 0))

    before = refs(engine.metrics.snapshot()["counters"])
    _drive(client, payloads)
    after = refs(engine.metrics.snapshot()["counters"])
    hot, cold, miss = (a - b for a, b in zip(after, before))
    return (hot + cold) / (hot + cold + miss)


def warm_restart_phase(warm_requests: int = 128, probe_requests: int = 64):
    sched = SchedulerConfig(max_batch=8, max_queue=256)
    snapdir = tempfile.mkdtemp(prefix="chaos_snap_")
    # pre-crash epoch: warm the cache, then measure the closed-loop probe
    eng1 = _make_engine(pace_s=0.0, sched=sched)
    warm = _payloads(eng1.cfg, warm_requests, seed=11)
    probe = _payloads(eng1.cfg, probe_requests, seed=13)
    server1 = GatewayServer({"score": EnginePump(eng1, "score")},
                            snapshot_dir=snapdir).start()
    client1 = GatewayClient(server1.url, timeout_s=20.0)
    _drive(client1, warm)
    hit_pre = _probe_hit_rate(eng1, client1, probe)
    server1.stop()     # graceful drain -> snapshot saved
    snap_path = os.path.join(snapdir, "score.cache.json")
    assert os.path.exists(snap_path), "drain did not write the cache snapshot"

    # warm restart: a FRESH engine restores the snapshot on startup
    eng2 = _make_engine(pace_s=0.0,
                        sched=SchedulerConfig(max_batch=8, max_queue=256))
    server2 = GatewayServer({"score": EnginePump(eng2, "score")},
                            snapshot_dir=snapdir).start()
    assert eng2.metrics.snapshot()["counters"].get("snapshot_restores") == 1, \
        "warm restart did not restore the snapshot"
    client2 = GatewayClient(server2.url, timeout_s=20.0)
    hit_post = _probe_hit_rate(eng2, client2, probe)
    server2.stop()

    # cold-restart control: same fresh engine, no snapshot
    eng3 = _make_engine(pace_s=0.0,
                        sched=SchedulerConfig(max_batch=8, max_queue=256))
    server3 = GatewayServer({"score": EnginePump(eng3, "score")}).start()
    client3 = GatewayClient(server3.url, timeout_s=20.0)
    hit_cold = _probe_hit_rate(eng3, client3, probe)
    server3.stop()

    assert hit_post >= hit_pre - 0.01, (
        f"post-restore hit rate {hit_post:.2%} fell more than 1pt below "
        f"the pre-crash baseline {hit_pre:.2%}")
    assert hit_post >= hit_cold, (
        f"warm restart ({hit_post:.2%}) must not lose to a cold start "
        f"({hit_cold:.2%})")
    return {"hit_pre": hit_pre, "hit_post": hit_post, "hit_cold": hit_cold,
            "delta_pt": (hit_post - hit_pre) * 100.0,
            "cold_penalty_pt": (hit_pre - hit_cold) * 100.0,
            "snapshot_path": snap_path}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--requests", type=int, default=192,
                    help="fault-phase request count")
    args = ap.parse_args(argv)

    fault = fault_phase(args.requests)
    o, inj = fault["outcomes"], fault["injections"]
    print(f"[chaos-smoke] fault phase: {sum(o.values())} requests conserved "
          f"(done={o['done']} failed={o['failed']} 503s="
          f"{o['rejected'] + o['shed'] + o['unavailable']}); injected "
          f"{inj.get('pump_crash', 0)} crashes -> "
          f"{fault['supervisor']['restarts']} restarts; "
          f"{inj.get('conn_reset', 0)} resets -> "
          f"{fault['dedupe']['replays']} deduped replays")

    brk = breaker_phase()
    print(f"[chaos-smoke] breaker: persistent fault paid "
          f"{brk['forwards_during_fault']} x 500 (threshold="
          f"{brk['threshold']}), then shed 503 until recovery probe closed "
          f"the circuit")

    det = determinism_phase()
    print(f"[chaos-smoke] determinism: 2 same-seed runs, "
          f"{det['events']} injections each, logs identical")

    warm = warm_restart_phase()
    print(f"[chaos-smoke] warm restart: hit {warm['hit_pre']:.2%} pre-crash, "
          f"{warm['hit_post']:.2%} restored ({warm['delta_pt']:+.2f}pt), "
          f"{warm['hit_cold']:.2%} cold control "
          f"(penalty {warm['cold_penalty_pt']:.2f}pt)")

    fault.pop("log", None)
    fault.pop("order", None)
    fault.pop("snapshot", None)
    out = {
        "fault_phase": fault,
        "breaker_phase": brk,
        "determinism": det,
        "warm_restart": warm,
        "verdict": {
            "requests_conserved": True,
            "restarts_match_crashes": True,
            "deduped_replays": fault["dedupe"]["replays"],
            "breaker_500_tail": brk["forwards_during_fault"],
            "injection_log_deterministic": det["identical"],
            "hit_pre": warm["hit_pre"],
            "hit_post": warm["hit_post"],
            "hit_cold": warm["hit_cold"],
            "restore_delta_pt": warm["delta_pt"],
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"[chaos-smoke] OK — wrote {args.out}")
    return out


if __name__ == "__main__":
    main()  # assertion failure -> traceback + non-zero exit
