"""`make gateway-smoke` — loopback load test of the repro.gateway subsystem.

Unlike `serve_smoke` (virtual clock, single thread), everything here runs
over real threads and real TCP sockets on localhost, so the GRASP serving
claims are re-checked under true concurrency:

1. **closed loop** — worker threads drive the zipf stream from
   `serve_smoke` through `/v1/score`; every request must come back done,
   and the GRASP cache hit rate must stay >= the *unpinned* baseline
   recorded in ``BENCH_serve.json`` (re-derived on a virtual clock when
   the file is absent).
2. **open loop, 2x overload** — a deterministically paced engine (fixed
   batch service time) is offered twice its capacity with deadlines
   attached. Asserts the scheduler's bound survives sockets: no *served*
   request exceeds ``deadline + one batch service time``; every submitted
   request resolves (done/shed/rejected — zero hangs, conservation is
   checked server-side too); and the client's bounded-backoff retries
   recover at least one request through transient 503s.

Emits both phases plus a verdict to ``BENCH_gateway.json``.

    PYTHONPATH=src python -m benchmarks.gateway_smoke [--out BENCH_gateway.json]

Non-tier-1: wired into scripts/verify.sh after serve_smoke (which
produces the baseline file it reads). Wall-clock is bounded: every join
carries a timeout and the phases offer finite load.
"""
from __future__ import annotations

import argparse
import json
import os
import threading
import time

import numpy as np

from repro.configs import base as cfgs
from repro.data.pipeline import zipf_ids
from repro.gateway import GatewayClient, GatewayError, GatewayServer
from repro.gateway.pump import EnginePump
from repro.serve.cache import CacheConfig
from repro.serve.engine import RecsysServeEngine
from repro.serve.scheduler import SchedulerConfig

CANDIDATES = 16
ZIPF_A = 1.1
CACHE_ROWS = 128           # same capacity as serve_smoke: 128 of 1000 rows
JOIN_TIMEOUT_S = 120.0     # hard bound on any phase's wall clock


class PacedRecsysEngine(RecsysServeEngine):
    """Recsys engine whose forward is padded to a fixed wall time, so the
    overload phase has a deterministic capacity (batch/pace_s rps) on any
    host — the real model forward still runs first."""

    def __init__(self, *args, pace_s: float = 0.0, **kw) -> None:
        super().__init__(*args, **kw)
        self.pace_s = float(pace_s)

    def forward(self, payloads):
        t0 = time.monotonic()
        out = super().forward(payloads)
        left = self.pace_s - (time.monotonic() - t0)
        if left > 0:
            time.sleep(left)
        return out


def _make_engine(pace_s: float, sched: SchedulerConfig) -> PacedRecsysEngine:
    import jax
    from repro.nn import recsys as recsys_mod

    cfg = cfgs.reduced(cfgs.get_arch("mind"))   # 1000 items, d=16
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    eng = PacedRecsysEngine(
        params, cfg,
        CacheConfig(budget_bytes=CACHE_ROWS * cfg.embed_dim * 4,
                    hot_fraction=0.5, policy="rrpv", tile_e=128),
        sched, pace_s=pace_s)
    eng.warmup(candidates=CANDIDATES)
    return eng


def _payloads(cfg, n: int, seed: int = 0):
    """Same draw order as serve_smoke's stream: hist then candidates."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        out.append({
            "hist": zipf_ids(rng, (cfg.hist_len,), cfg.n_items, a=ZIPF_A),
            "candidates": zipf_ids(rng, (CANDIDATES,), cfg.n_items, a=ZIPF_A),
        })
    return out


def _unpinned_baseline(out_dir: str = ".") -> float:
    """Best unpinned hit rate: read BENCH_serve.json, else re-derive it on
    the virtual clock exactly as serve_smoke does."""
    path = os.path.join(out_dir, "BENCH_serve.json")
    if os.path.exists(path):
        with open(path) as f:
            runs = json.load(f)["hit_rate_comparison"]
        return max(runs["baseline_rrpv"]["hit_rate"],
                   runs["baseline_lru"]["hit_rate"])
    from repro.serve.engine import StreamConfig, run_recsys_stream

    cfg = cfgs.reduced(cfgs.get_arch("mind"))
    budget = CACHE_ROWS * cfg.embed_dim * 4
    sched = SchedulerConfig(max_batch=8, max_queue=64)
    stream = StreamConfig(requests=128, qps=500.0, candidates=CANDIDATES,
                          zipf_a=ZIPF_A, deadline_s=None)
    best = 0.0
    for policy in ("rrpv", "lru"):
        cc = CacheConfig(budget_bytes=budget, hot_fraction=0.0,
                         policy=policy, tile_e=128)
        best = max(best, run_recsys_stream(cfg, cc, sched, stream,
                                           service_time_s=1e-3)["hit_rate"])
    return best


# ---------------------------------------------------------------------------
# phase 1: closed-loop hit rate over sockets
# ---------------------------------------------------------------------------
def closed_loop(requests: int, workers: int = 4):
    sched = SchedulerConfig(max_batch=8, max_queue=256)
    eng = _make_engine(pace_s=2e-3, sched=sched)
    payloads = _payloads(eng.cfg, requests)
    server = GatewayServer({"score": EnginePump(eng, "score")}).start()
    client = GatewayClient(server.url, timeout_s=30.0)
    it = iter(range(requests))
    it_lock = threading.Lock()
    done, errors = [], []

    def worker():
        while True:
            with it_lock:
                i = next(it, None)
            if i is None:
                return
            try:
                s = client.score(payloads[i]["hist"],
                                 payloads[i]["candidates"], timeout_s=30.0)
                assert s.shape == (CANDIDATES,) and np.isfinite(s).all()
                done.append(i)
            except Exception as e:  # noqa: BLE001 — tallied + asserted below
                errors.append((i, repr(e)))

    threads = [threading.Thread(target=worker, daemon=True)
               for _ in range(workers)]
    t0 = time.monotonic()
    for t in threads:
        t.start()
    for t in threads:
        t.join(JOIN_TIMEOUT_S)
    hung = [t for t in threads if t.is_alive()]
    wall = time.monotonic() - t0
    snap = eng.metrics.snapshot()
    server.stop()
    assert not hung, f"closed loop: {len(hung)} worker(s) still alive"
    assert not errors, f"closed loop: {len(errors)} failed, first {errors[:3]}"
    assert len(done) == requests
    assert snap["counters"]["completed"] == requests
    return {"snapshot": snap, "wall_s": wall, "requests": requests,
            "workers": workers, "hit_rate": snap["hit_rate"]}


# ---------------------------------------------------------------------------
# phase 2: open-loop 2x overload with deadlines
# ---------------------------------------------------------------------------
def overload(requests: int = 512, pace_s: float = 0.01,
             deadline_ms: float = 40.0, max_queue: int = 64):
    sched = SchedulerConfig(max_batch=8, max_queue=max_queue,
                            default_deadline_s=deadline_ms / 1e3)
    eng = _make_engine(pace_s=pace_s, sched=sched)
    payloads = _payloads(eng.cfg, requests, seed=1)
    server = GatewayServer({"score": EnginePump(eng, "score")}).start()
    client = GatewayClient(server.url, timeout_s=20.0, retries=8,
                           backoff_s=0.02, backoff_cap_s=0.3)

    capacity_rps = sched.max_batch / pace_s
    offered_rps = 2.0 * capacity_rps            # the 2x-overload point
    start = time.monotonic() + 0.25
    outcomes = {"done": 0, "rejected": 0, "shed": 0, "timeout": 0, "error": 0}
    out_lock = threading.Lock()

    def fire(i: int):
        time.sleep(max(0.0, start + i / offered_rps - time.monotonic()))
        try:
            client.score(payloads[i]["hist"], payloads[i]["candidates"],
                         deadline_ms=deadline_ms, timeout_s=20.0)
            kind = "done"
        except GatewayError as e:
            kind = e.kind if e.kind in outcomes else "error"
        except Exception:  # noqa: BLE001 — tally, never die silently
            kind = "error"
        with out_lock:
            outcomes[kind] += 1

    threads = [threading.Thread(target=fire, args=(i,), daemon=True)
               for i in range(requests)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(JOIN_TIMEOUT_S)
    hung = sum(t.is_alive() for t in threads)
    server.stop()                               # graceful drain
    snap = eng.metrics.snapshot()
    c = snap["counters"]

    # -- liveness: every submitted request resolved ---------------------
    assert hung == 0, f"overload: {hung} request thread(s) hung"
    assert sum(outcomes.values()) == requests
    assert outcomes["timeout"] == 0 and outcomes["error"] == 0, outcomes
    assert c.get("failed", 0) == 0
    # server-side conservation: everything admitted was completed or shed
    assert c["admitted"] == c.get("completed", 0) + c.get("shed", 0), c

    # -- the tail bound survives real sockets/threads -------------------
    service_max = snap["latency"]["service"]["max_s"]
    e2e_max = snap["latency"]["e2e"]["max_s"]
    bound = deadline_ms / 1e3 + service_max + 1e-6
    assert e2e_max <= bound, (
        f"served worst-case e2e {e2e_max*1e3:.1f}ms exceeds deadline+batch "
        f"bound {bound*1e3:.1f}ms")

    # -- overload actually overloads, and the system still serves -------
    dropped = c.get("shed", 0) + c.get("rejected", 0)
    assert dropped > 0, "2x overload must shed/reject some load"
    assert c.get("completed", 0) > 0, "shed-load must not starve the engine"

    # -- client retries recover through transient 503s ------------------
    stats = dict(client.stats)
    assert stats["retries_503"] > 0, "overload produced no 503 retries"
    assert stats["recovered"] > 0, (
        "no request recovered via retry-after-503")

    return {
        "snapshot": snap, "outcomes": outcomes, "client": stats,
        "offered_rps": offered_rps, "capacity_rps": capacity_rps,
        "deadline_ms": deadline_ms, "pace_s": pace_s,
        "e2e_max_s": e2e_max, "service_max_s": service_max,
        "bound_s": bound, "p99_s": snap["latency"]["e2e"]["p99_s"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_gateway.json")
    ap.add_argument("--requests", type=int, default=128,
                    help="closed-loop request count (matches serve_smoke)")
    ap.add_argument("--overload-requests", type=int, default=512)
    args = ap.parse_args(argv)

    base = _unpinned_baseline(os.path.dirname(args.out) or ".")
    closed = closed_loop(args.requests)
    print(f"[gateway-smoke] closed loop: {closed['requests']} served over "
          f"sockets in {closed['wall_s']:.2f}s; GRASP hit="
          f"{closed['hit_rate']:.2%} vs unpinned baseline {base:.2%}")
    assert closed["hit_rate"] >= base, (
        f"GRASP hit rate {closed['hit_rate']:.2%} under concurrency fell "
        f"below the unpinned baseline {base:.2%}")
    assert closed["hit_rate"] > 0.5          # a real cache, not pass-through

    over = overload(args.overload_requests)
    o, cs = over["outcomes"], over["client"]
    print(f"[gateway-smoke] overload 2x: done={o['done']} shed={o['shed']} "
          f"rejected={o['rejected']} | retries={cs['retries_503']} "
          f"recovered={cs['recovered']} | e2e max="
          f"{over['e2e_max_s']*1e3:.1f}ms bound={over['bound_s']*1e3:.1f}ms")

    out = {
        "closed_loop": closed,
        "overload": over,
        "verdict": {
            "gateway_hit_rate": closed["hit_rate"],
            "unpinned_baseline_hit_rate": base,
            "margin": closed["hit_rate"] - base,
            "overload_e2e_max_s": over["e2e_max_s"],
            "overload_bound_s": over["bound_s"],
            "retries_recovered": cs["recovered"],
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"[gateway-smoke] OK — GRASP beats unpinned by "
          f"{(closed['hit_rate'] - base) * 1e2:.1f}pt over real sockets; "
          f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()  # assertion failure -> traceback + non-zero exit
