"""Generate the EXPERIMENTS.md dry-run + roofline tables from
reports/dryrun_final.json and splice them into the hand-written template.

    PYTHONPATH=src python -m benchmarks.make_experiments_md
"""
from __future__ import annotations

import json
import os


def fmt_cell_table(recs, mesh):
    rows = [r for r in recs if r["mesh"] == mesh]
    out = [
        "| arch | shape | status | bytes/dev | compile | compute s | "
        "memory s | collective s | dominant | useful-FLOP | roofline-frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | | |")
            continue
        bpd = r.get("bytes_per_device") or 0
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {bpd/1e9:.2f}GB "
            f"| {r['compile_s']:.0f}s | {r['compute_s']:.3g} "
            f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
            f"| {r['dominant']} | {r['useful_flop_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(out)


def main():
    with open("reports/dryrun_final.json") as f:
        recs = json.load(f)
    ok = sum(r["status"] == "ok" for r in recs)
    single = fmt_cell_table(recs, "single")
    multi = fmt_cell_table(recs, "multi")

    doms = {}
    for r in recs:
        if r["status"] == "ok":
            doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1

    with open("EXPERIMENTS.md") as f:
        text = f.read()
    text = text.replace("<!--DRYRUN_SUMMARY-->",
                        f"**{ok}/{len(recs)} cells compiled** "
                        f"(40 arch x shape cells x 2 meshes). "
                        f"Dominant-term distribution: {doms}.")
    text = text.replace("<!--TABLE_SINGLE-->", single)
    text = text.replace("<!--TABLE_MULTI-->", multi)
    with open("EXPERIMENTS.md", "w") as f:
        f.write(text)
    print(f"EXPERIMENTS.md updated: {ok}/{len(recs)} cells")


if __name__ == "__main__":
    main()
