"""Shared machinery for the paper-reproduction benchmarks.

Runs the (app x dataset x reordering x policy) matrix on the scaled
datasets, caching every simulation in reports/paper_eval.json so repeated
benchmark invocations are incremental.
"""
from __future__ import annotations

import json
import os
import time
from functools import lru_cache
from typing import Dict, Optional

import numpy as np

from repro.core import cachesim
from repro.core.reorder import reorder_cost_model, reorder_ranks
from repro.graph import datasets, traces
from repro.graph.csr import apply_reorder

CACHE_PATH = os.path.join("reports", "paper_eval.json")
SCALE = 14           # log2 vertices of the scaled datasets
APPS = ("bc", "sssp", "pr", "prd", "radii")
HIGH_SKEW = datasets.HIGH_SKEW
ADVERSARIAL = datasets.ADVERSARIAL

_cache: Optional[Dict] = None


def _load_cache() -> Dict:
    global _cache
    if _cache is None:
        if os.path.exists(CACHE_PATH):
            with open(CACHE_PATH) as f:
                _cache = json.load(f)
        else:
            _cache = {}
    return _cache


def _save_cache():
    os.makedirs(os.path.dirname(CACHE_PATH), exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(_cache, f)


@lru_cache(maxsize=64)
def reordered_graph(ds: str, technique: str, direction: str = "pull"):
    g = datasets.load(ds, scale=SCALE)
    if technique == "identity":
        return g
    return apply_reorder(g, reorder_ranks(g, technique, direction))


@lru_cache(maxsize=64)
def trace_for(ds: str, app: str, technique: str, llc_mult: float = 1.0,
              hints: bool = True):
    g2 = reordered_graph(ds, technique,
                         traces.APPS[app].direction)
    llc = int(datasets.scaled_llc_bytes(
        ds, g2, elem_bytes=traces.APPS[app].elem_bytes) * llc_mult)
    llc = max(llc, 16 * 1024)
    tr, plan = traces.generate_trace(g2, app, llc, max_records=1_200_000,
                                     hints_enabled=hints)
    return tr, llc


def sim(ds: str, app: str, technique: str, policy: str,
        llc_mult: float = 1.0) -> Dict:
    """Cached simulation -> dict(miss_rate, hits, accesses, wall_s)."""
    key = f"{ds}|{app}|{technique}|{policy}|{llc_mult}|s{SCALE}"
    cache = _load_cache()
    if key in cache:
        return cache[key]
    tr, llc = trace_for(ds, app, technique, llc_mult)
    t0 = time.time()
    r = cachesim.simulate(tr, policy, llc)
    rec = {
        "miss_rate": r.miss_rate,
        "hits": int(r.hits),
        "misses": int(r.misses),
        "accesses": int(r.accesses),
        "hits_by_hint": [int(x) for x in r.hits_by_hint],
        "accesses_by_hint": [int(x) for x in r.accesses_by_hint],
        "wall_s": round(time.time() - t0, 3),
    }
    cache[key] = rec
    _save_cache()
    return rec


def miss_reduction(base: Dict, other: Dict) -> float:
    """Fraction of baseline misses eliminated (paper Figs. 5, 11)."""
    return (base["misses"] - other["misses"]) / max(base["misses"], 1)


def speedup(base: Dict, other: Dict, pm: Optional[cachesim.PerfModel] = None) -> float:
    pm = pm or cachesim.PerfModel()

    def as_res(d, name):
        return cachesim.SimResult(
            name, d["accesses"], d["hits"],
            np.asarray(d["hits_by_hint"]), np.asarray(d["accesses_by_hint"]),
        )

    return pm.speedup(as_res(base, "base"), as_res(other, "other"))


def gmean(xs) -> float:
    xs = np.asarray(list(xs), dtype=np.float64)
    return float(np.exp(np.log(np.maximum(xs, 1e-9)).mean()))
