"""`make perf-smoke` — the tracked perf baseline for the two hottest paths.

Three sections, every speed number guarded by an equality invariant so a
faster wrong answer can never pass:

  lookup      vectorized ``EmbeddingCache.lookup`` vs the retained
              pre-vectorization loop (``serve.refcache``) over identical
              id streams at several batch sizes and skews. Asserts
              bit-identical outputs (== ``table[ids]``), identical
              hit/miss/bypass counters and cold-region metadata, and the
              acceptance floor: >= 3x rows/s at batch 256 on the
              zipf a=1.1 stream.
  dist        ``make_grasp_gin_step`` pipelined (overlap=True, the
              default) vs sequential (overlap=False) on the simulated
              8-device mesh: asserts bit-identical loss AND params over
              multiple steps, reports per-step wall time and collective
              counts (the pipelined exchange issues L fused all_gathers
              per step instead of 2L).
  hot_gather  the Pallas hot-region gather kernel microbench
              (interpret mode on CPU), checked against the dense
              reference gather.

Emits everything to ``BENCH_perf.json`` — the file README perf figures
are refreshed from, and the trajectory regressions are caught against.

    PYTHONPATH=src python -m benchmarks.perf_smoke [--out BENCH_perf.json]

Non-tier-1: wired into scripts/verify.sh after the tier-1 steps.
"""
from __future__ import annotations

import os

# must precede the first jax import: the dist section needs 8 host devices
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import numpy as np

LOOKUP_BATCHES = (64, 256, 1024)
LOOKUP_SKEWS = ("uniform", "zipf_1.1", "zipf_1.4")
LOOKUP_ROUNDS = 50
ACCEPT_BATCH, ACCEPT_SKEW, ACCEPT_SPEEDUP = 256, "zipf_1.1", 3.0


def _stream(skew: str, batch: int, n_rows: int, rounds: int, seed: int):
    from repro.data.pipeline import zipf_ids

    rng = np.random.default_rng(seed)
    if skew == "uniform":
        return [rng.integers(0, n_rows, batch) for _ in range(rounds)]
    a = float(skew.split("_")[1])
    return [zipf_ids(rng, (batch,), n_rows, a=a) for _ in range(rounds)]


def bench_lookup():
    """Vectorized vs reference lookup: equivalence pass, then timed pass."""
    from repro.serve.cache import CacheConfig, EmbeddingCache
    from repro.serve.refcache import ReferenceEmbeddingCache

    n_rows, dim = 1000, 16
    cc = CacheConfig(budget_bytes=128 * dim * 4, hot_fraction=0.5,
                     policy="rrpv", use_kernel=False)
    rng = np.random.default_rng(0)
    table = rng.standard_normal((n_rows, dim)).astype(np.float32)

    results = {}
    for skew in LOOKUP_SKEWS:
        for batch in LOOKUP_BATCHES:
            stream = _stream(skew, batch, n_rows, LOOKUP_ROUNDS, seed=7)

            # --- equivalence: same stream through both, bit-for-bit ---
            vec = EmbeddingCache(table, cc)
            ref = ReferenceEmbeddingCache(table, cc)
            for ids in stream:
                o_vec, s_vec = vec.lookup(ids)
                o_ref, s_ref = ref.lookup(ids)
                o_vec, o_ref = np.asarray(o_vec), np.asarray(o_ref)
                assert (o_vec == table[np.asarray(ids, np.int64)]).all(), \
                    "vectorized lookup output differs from table[ids]"
                assert (o_vec == o_ref).all(), "vectorized != reference rows"
                assert s_vec == s_ref, f"stats drift: {s_vec} != {s_ref}"
            for attr in ("_slot_id", "_slot_rrpv", "_slot_ts", "_id_slot"):
                assert (getattr(vec, attr) == getattr(ref, attr)).all(), \
                    f"cold-region metadata drift in {attr}"
            for key in ("hot_hits", "cold_hits", "misses", "bypassed"):
                cv = vec.metrics.counters.get(key, 0)
                cr = ref.metrics.counters.get(key, 0)
                assert cv == cr, f"counter {key} drift: {cv} != {cr}"
            vec.check_consistency()
            # ServeMetrics semantics: can go negative under heavy
            # thrashing (same-batch fills displaced again count as misses)
            hit_rate = vec.metrics.hit_rate
            assert hit_rate == ref.metrics.hit_rate, "hit-rate drift"

            # --- timing: fresh caches, short warmup, full stream ------
            rates = {}
            for name, cls in (("vectorized", EmbeddingCache),
                              ("reference", ReferenceEmbeddingCache)):
                cache = cls(table, cc)
                for ids in stream[:5]:
                    cache.lookup(ids)
                t0 = time.perf_counter()
                for ids in stream:
                    cache.lookup(ids)
                dt = time.perf_counter() - t0
                rates[name] = batch * len(stream) / dt
            speedup = rates["vectorized"] / rates["reference"]
            results[f"{skew}_b{batch}"] = {
                "batch": batch,
                "skew": skew,
                "rows_per_s_vectorized": rates["vectorized"],
                "rows_per_s_reference": rates["reference"],
                "speedup": speedup,
                "hit_rate": hit_rate,
            }
            print(f"[perf-smoke] lookup {skew:9s} b={batch:5d}: "
                  f"vec={rates['vectorized']:>10.0f} rows/s "
                  f"ref={rates['reference']:>8.0f} rows/s "
                  f"({speedup:6.1f}x, hit={hit_rate:.2%})")

    accept = results[f"{ACCEPT_SKEW}_b{ACCEPT_BATCH}"]
    assert accept["speedup"] >= ACCEPT_SPEEDUP, (
        f"vectorized lookup must be >= {ACCEPT_SPEEDUP}x the reference at "
        f"batch {ACCEPT_BATCH} on {ACCEPT_SKEW} "
        f"(got {accept['speedup']:.2f}x)")
    return results


def bench_dist(steps: int = 5):
    """Pipelined vs sequential GRASP exchange: bit-exact, then timed."""
    import jax
    import jax.numpy as jnp

    if jax.device_count() != 8:
        print("[perf-smoke] dist: skipped (needs 8 host devices)")
        return {"skipped": True}

    from repro.configs import base as cfgs
    from repro.core.reorder import reorder_ranks
    from repro.dist import collectives as coll
    from repro.graph import generate
    from repro.graph.csr import apply_reorder
    from repro.launch.mesh import make_debug_mesh
    from repro.nn import gnn as gnn_mod
    from repro.train import optimizer as opt_mod

    P, n_layers = 8, 3
    mesh = make_debug_mesh(2, 4)
    g = generate.rmat(10, 8, seed=3)
    g = apply_reorder(g, reorder_ranks(g, "dbg"))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, P, hot=256,
                                   pub_frac=1.0, edge_slack=3.0)
    part = coll.grasp_partition(g, spec)
    assert part["dropped"] == 0

    cfg = cfgs.GNNConfig(name="perf", kind="gin", n_layers=n_layers,
                         d_hidden=32)
    d_feat, n_classes = 16, 5
    rng = np.random.default_rng(0)
    params0 = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=d_feat)
    opt_init, opt_update = opt_mod.make(opt_mod.OptConfig(lr=1e-3))

    x = rng.standard_normal((spec.num_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, spec.num_nodes).astype(np.int32)
    lab_own = np.zeros((P, spec.n_own), np.int32)
    for p in range(P):
        hot_ids = np.arange(p * spec.hot_per_dev, (p + 1) * spec.hot_per_dev)
        cold_ids = spec.hot + np.arange(p * spec.cold_per_dev,
                                        (p + 1) * spec.cold_per_dev)
        lab_own[p] = labels[np.concatenate([hot_ids, cold_ids])]
    batch = dict(
        x_hot=jnp.asarray(x[:spec.hot]),
        x_cold=jnp.asarray(x[spec.hot:].reshape(P, spec.cold_per_dev, d_feat)),
        esrc=jnp.asarray(part["esrc"]), edst=jnp.asarray(part["edst"]),
        emask=jnp.asarray(part["emask"]), pub=jnp.asarray(part["pub"]),
        labels=jnp.asarray(lab_own))

    out = {"num_nodes": int(spec.num_nodes), "num_edges": int(g.num_edges),
           "layers": n_layers, "steps": steps, "devices": P,
           "collectives_per_step": {"sequential": 2 * n_layers,
                                    "pipelined": n_layers}}
    traj, final_params = {}, {}
    for name, overlap in (("sequential", False), ("pipelined", True)):
        step, _ = coll.make_grasp_gin_step(spec, cfg, d_feat, n_classes,
                                           mesh, opt_update, overlap=overlap)
        p_, o_ = params0, opt_init(params0)
        losses = []
        with jax.set_mesh(mesh):
            jstep = jax.jit(step)
            p_, o_, m = jstep(p_, o_, batch)        # compile + step 1
            losses.append(float(m["loss"]))
            t0 = time.perf_counter()
            for _ in range(steps - 1):
                p_, o_, m = jstep(p_, o_, batch)
                losses.append(float(m["loss"]))
            jax.block_until_ready(p_)
            dt = time.perf_counter() - t0
        traj[name] = losses
        final_params[name] = p_
        out[name] = {"step_ms": dt / max(steps - 1, 1) * 1e3,
                     "losses": losses}
        print(f"[perf-smoke] dist {name:10s}: "
              f"{out[name]['step_ms']:7.1f} ms/step  loss[0]={losses[0]:.6f}")

    assert traj["sequential"] == traj["pipelined"], (
        "pipelined GRASP step loss diverged from sequential: "
        f"{traj['sequential']} != {traj['pipelined']}")
    leaves_s = jax.tree_util.tree_leaves(final_params["sequential"])
    leaves_p = jax.tree_util.tree_leaves(final_params["pipelined"])
    assert all(bool((a == b).all()) for a, b in zip(leaves_s, leaves_p)), \
        "pipelined GRASP step params diverged from sequential"
    out["bit_exact"] = True
    out["speedup"] = (out["sequential"]["step_ms"]
                      / out["pipelined"]["step_ms"])
    return out


def bench_hot_gather(iters: int = 10):
    """Pinned-hot-region Pallas gather microbench (interpret on CPU)."""
    import jax.numpy as jnp

    from repro.kernels.hot_gather.hot_gather import hot_gather_hot_part

    hot, d, e, tile = 512, 128, 4096, 512
    rng = np.random.default_rng(0)
    table = rng.standard_normal((hot, d)).astype(np.float32)
    idx = rng.integers(-1, hot, e).astype(np.int32)   # -1 = cold fixup rows
    table_j, idx_j = jnp.asarray(table), jnp.asarray(idx)

    rows = np.asarray(hot_gather_hot_part(table_j, idx_j, tile_e=tile,
                                          interpret=True))
    want = np.where((idx >= 0)[:, None], table[np.clip(idx, 0, hot - 1)], 0.0)
    assert (rows == want).all(), "hot_gather kernel != dense reference gather"

    t0 = time.perf_counter()
    for _ in range(iters):
        hot_gather_hot_part(table_j, idx_j, tile_e=tile,
                            interpret=True).block_until_ready()
    dt = time.perf_counter() - t0
    out = {"hot_rows": hot, "dim": d, "idx_len": e, "tile_e": tile,
           "interpret": True, "rows_per_s": e * iters / dt}
    print(f"[perf-smoke] hot_gather (interpret): "
          f"{out['rows_per_s']:.0f} rows/s over {iters} iters")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_perf.json")
    ap.add_argument("--dist-steps", type=int, default=5)
    args = ap.parse_args(argv)

    lookup = bench_lookup()
    dist = bench_dist(steps=args.dist_steps)
    hot_gather = bench_hot_gather()

    accept = lookup[f"{ACCEPT_SKEW}_b{ACCEPT_BATCH}"]
    out = {
        "lookup": lookup,
        "dist": dist,
        "hot_gather": hot_gather,
        "verdict": {
            "lookup_speedup_at_accept": accept["speedup"],
            "lookup_accept_floor": ACCEPT_SPEEDUP,
            "dist_bit_exact": dist.get("bit_exact", None),
            "dist_speedup": dist.get("speedup", None),
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"[perf-smoke] OK — lookup {accept['speedup']:.1f}x at "
          f"b{ACCEPT_BATCH}/{ACCEPT_SKEW} (floor {ACCEPT_SPEEDUP}x); "
          f"wrote {args.out}")
    return out


if __name__ == "__main__":
    main()  # assertion failure -> traceback + non-zero exit
