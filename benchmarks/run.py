"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is the
benchmark's own wall time per simulated datapoint; ``derived`` is the paper
metric being reproduced, with the paper's reported value noted inline.

    PYTHONPATH=src python -m benchmarks.run [--only fig5,fig11] [--fast]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from benchmarks import paper_eval as pe


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def table1_skew():
    """Paper Table I: hot-vertex fraction + edge coverage per dataset."""
    from repro.core import hotset
    from repro.graph import datasets

    for ds in pe.HIGH_SKEW + pe.ADVERSARIAL:
        t0 = time.time()
        g = datasets.load(ds, scale=pe.SCALE)
        st = hotset.skew_stats(hotset.reuse_degree(g, "pull"))
        _row(
            f"table1_skew_{ds}", (time.time() - t0) * 1e6,
            f"hot%={st.hot_fraction:.1%} edge_cov={st.edge_coverage:.1%} "
            f"(paper: 9-26% / 81-93%)",
        )


def fig2_access_classification(apps=("pr", "prd"), ds="tw"):
    """Paper Fig. 2: Property Array dominates LLC accesses (78-94%)."""
    for app in apps:
        t0 = time.time()
        tr, _ = pe.trace_for(ds, app, "dbg")
        prop = float(((tr.pc == 0) | (tr.pc == 3)).mean())
        _row(f"fig2_property_share_{app}_{ds}", (time.time() - t0) * 1e6,
             f"property_access_share={prop:.1%} (paper: 78-94%)")


def fig5_miss_reduction(fast=False):
    """Paper Fig. 5: LLC miss reduction over RRIP, DBG-reordered datasets.
    Paper: GRASP avg +6.4% (max 14.2%); SHiP-MEM -4.8%; Hawkeye -22.7%;
    Leeway +1.1%."""
    apps = ("pr",) if fast else pe.APPS
    schemes = ("grasp", "ship_mem", "hawkeye", "leeway")
    out = {s: [] for s in schemes}
    t0 = time.time()
    n = 0
    for app in apps:
        for ds in pe.HIGH_SKEW:
            base = pe.sim(ds, app, "dbg", "rrip")
            for s in schemes:
                r = pe.sim(ds, app, "dbg", s)
                out[s].append(pe.miss_reduction(base, r))
                n += 1
    us = (time.time() - t0) * 1e6 / max(n, 1)
    paper = {"grasp": "+6.4%", "ship_mem": "-4.8%", "hawkeye": "-22.7%",
             "leeway": "+1.1%"}
    for s in schemes:
        arr = np.asarray(out[s])
        _row(f"fig5_missred_{s}", us,
             f"avg={arr.mean():+.1%} max={arr.max():+.1%} min={arr.min():+.1%} "
             f"(paper avg {paper[s]})")
    grasp = np.asarray(out["grasp"])
    _row("fig5_grasp_no_regression", us,
         f"all_datapoints_improve={bool((grasp > -1e-6).all())} (paper: yes)")


def fig6_speedup(fast=False):
    """Paper Fig. 6: speed-up over RRIP (proxy model). Paper: GRASP avg
    +5.2% (max 10.2%); SHiP-MEM -5.5%; Hawkeye -16.2%; Leeway +0.9%."""
    apps = ("pr",) if fast else pe.APPS
    schemes = ("grasp", "ship_mem", "hawkeye", "leeway")
    out = {s: [] for s in schemes}
    t0, n = time.time(), 0
    for app in apps:
        for ds in pe.HIGH_SKEW:
            base = pe.sim(ds, app, "dbg", "rrip")
            for s in schemes:
                out[s].append(pe.speedup(base, pe.sim(ds, app, "dbg", s)))
                n += 1
    us = (time.time() - t0) * 1e6 / max(n, 1)
    paper = {"grasp": "+5.2%", "ship_mem": "-5.5%", "hawkeye": "-16.2%",
             "leeway": "+0.9%"}
    for s in schemes:
        sp = pe.gmean(out[s]) - 1.0
        mx = max(out[s]) - 1.0
        _row(f"fig6_speedup_{s}", us,
             f"avg={sp:+.1%} max={mx:+.1%} (paper avg {paper[s]})")


def fig7_ablation(fast=False):
    """Paper Fig. 7: feature ablation. RRIP+Hints +3.3%; GRASP(Insertion)
    +5.0%; GRASP(full) +5.2% over RRIP."""
    apps = ("pr",) if fast else pe.APPS
    variants = ("rrip_hints", "grasp_insert", "grasp")
    out = {v: [] for v in variants}
    t0, n = time.time(), 0
    for app in apps:
        for ds in pe.HIGH_SKEW:
            base = pe.sim(ds, app, "dbg", "rrip")
            for v in variants:
                out[v].append(pe.speedup(base, pe.sim(ds, app, "dbg", v)))
                n += 1
    us = (time.time() - t0) * 1e6 / max(n, 1)
    paper = {"rrip_hints": "+3.3%", "grasp_insert": "+5.0%", "grasp": "+5.2%"}
    for v in variants:
        _row(f"fig7_{v}", us,
             f"avg={pe.gmean(out[v])-1:+.1%} (paper {paper[v]})")


def fig8_pinning(fast=False):
    """Paper Fig. 8: XMem PIN-X vs GRASP on high-skew. Paper: GRASP +5.2%;
    PIN-25 +0.4%; PIN-50 +1.1%; PIN-75 +2.0%; PIN-100 +2.5%."""
    apps = ("pr",) if fast else pe.APPS
    schemes = ("pin_25", "pin_50", "pin_75", "pin_100", "grasp")
    out = {s: [] for s in schemes}
    t0, n = time.time(), 0
    for app in apps:
        for ds in pe.HIGH_SKEW:
            base = pe.sim(ds, app, "dbg", "rrip")
            for s in schemes:
                out[s].append(pe.speedup(base, pe.sim(ds, app, "dbg", s)))
                n += 1
    us = (time.time() - t0) * 1e6 / max(n, 1)
    for s in schemes:
        _row(f"fig8_{s}", us, f"avg={pe.gmean(out[s])-1:+.1%}")


def fig9_adversarial(fast=False):
    """Paper Fig. 9: low-/no-skew robustness. GRASP max slowdown 0.1%;
    PIN-75/100 slow down up to 5.3%/14.2%."""
    apps = ("pr", "prd") if fast else pe.APPS
    schemes = ("grasp", "pin_75", "pin_100")
    for s in schemes:
        t0, n, sp = time.time(), 0, []
        for app in apps:
            for ds in pe.ADVERSARIAL:
                base = pe.sim(ds, app, "dbg", "rrip")
                sp.append(pe.speedup(base, pe.sim(ds, app, "dbg", s)))
                n += 1
        us = (time.time() - t0) * 1e6 / max(n, 1)
        _row(f"fig9_{s}_lowskew", us,
             f"avg={pe.gmean(sp)-1:+.1%} worst={min(sp)-1:+.1%} "
             f"(paper worst: grasp -0.1%, pin_75 -5.3%, pin_100 -14.2%)")


def fig10a_reordering(fast=False):
    """Paper Fig. 10(a): net software-reordering speed-up including
    reordering cost. Paper: Sort +2.6%, HubSort +0.6%, DBG +10.8%,
    Gorder -85.4%."""
    from repro.graph import datasets

    apps = ("pr",) if fast else ("pr", "prd")
    for tech in ("sort", "hubsort", "dbg", "gorder_lite"):
        t0, sp = time.time(), []
        for app in apps:
            for ds in pe.HIGH_SKEW:
                base = pe.sim(ds, app, "identity", "rrip")
                r = pe.sim(ds, app, tech, "rrip")
                g = datasets.load(ds, scale=pe.SCALE)
                cost_frac = pe.reorder_cost_model(tech, g.num_nodes,
                                                  g.num_edges) / 10.0
                s = pe.speedup(base, r) / (1.0 + cost_frac)
                sp.append(s)
        us = (time.time() - t0) * 1e6 / max(len(sp), 1)
        _row(f"fig10a_net_{tech}", us, f"avg={pe.gmean(sp)-1:+.1%}")


def fig10b_grasp_generality(fast=False):
    """Paper Fig. 10(b): GRASP over RRIP on top of each reordering.
    Paper: +4.4% (Sort), +4.2% (HubSort), +5.2% (DBG), +5.0% (Gorder)."""
    apps = ("pr",) if fast else ("pr", "sssp", "radii")
    for tech in ("sort", "hubsort", "dbg", "gorder_lite"):
        t0, sp = time.time(), []
        for app in apps:
            for ds in pe.HIGH_SKEW:
                base = pe.sim(ds, app, tech, "rrip")
                sp.append(pe.speedup(base, pe.sim(ds, app, tech, "grasp")))
        us = (time.time() - t0) * 1e6 / max(len(sp), 1)
        _row(f"fig10b_grasp_on_{tech}", us, f"avg={pe.gmean(sp)-1:+.1%}")


def fig11_table7_opt(fast=False):
    """Paper Fig. 11 + Table VII: % misses eliminated over LRU for RRIP /
    GRASP / OPT across LLC sizes. Paper @16MB: RRIP 15.2%, GRASP 19.7%,
    OPT 34.3%; GRASP is 57.5% of OPT's elimination."""
    apps = ("pr",) if fast else ("pr", "sssp")
    mults = (1.0,) if fast else (0.25, 0.5, 1.0, 2.0)
    for mult in mults:
        t0, elim = time.time(), {"rrip": [], "grasp": [], "opt": []}
        for app in apps:
            for ds in pe.HIGH_SKEW:
                base = pe.sim(ds, app, "dbg", "lru", llc_mult=mult)
                for s in elim:
                    elim[s].append(
                        pe.miss_reduction(base, pe.sim(ds, app, "dbg", s,
                                                       llc_mult=mult)))
        us = (time.time() - t0) * 1e6 / (len(elim["opt"]) * 3)
        r, g, o = (np.mean(elim[s]) for s in ("rrip", "grasp", "opt"))
        eff = g / max(o, 1e-9)
        _row(f"fig11_opt_llcx{mult}", us,
             f"rrip={r:.1%} grasp={g:.1%} opt={o:.1%} grasp/opt={eff:.1%} "
             f"(paper @1x: 15.2%/19.7%/34.3%, 57.5%)")


def table4_array_merging():
    """Paper Table IV: Property-Array merging speed-up (PR 40-52%). Modeled
    as one merged 16B-element array vs two separate 8B arrays: the merged
    layout halves the property cache lines touched per edge."""
    from repro.graph import datasets, traces as tr_mod
    from repro.core import cachesim as cs

    t0 = time.time()
    ds = "tw"
    g2 = pe.reordered_graph(ds, "dbg")
    llc = datasets.scaled_llc_bytes(ds, g2, elem_bytes=16)
    merged, _ = tr_mod.generate_trace(g2, "pr", llc, max_records=800_000)
    r_m = cs.simulate(merged, "rrip", llc)
    prop_mask = (merged.pc == 0) | (merged.pc == 3)
    offset = (g2.num_nodes * 16) // 64 * 2  # second array's line space
    dup_lines = np.concatenate([merged.line, merged.line[prop_mask] + offset])
    dup_hint = np.concatenate([merged.hint, merged.hint[prop_mask]])
    dup_pc = np.concatenate([merged.pc, merged.pc[prop_mask]])
    unmerged = cs.finalize_trace(dup_lines, dup_hint, dup_pc)
    r_u = cs.simulate(unmerged, "rrip", llc)
    pm = cs.PerfModel()
    t_m = r_m.hits * pm.llc_hit_cycles + r_m.misses * pm.mem_cycles
    t_u = r_u.hits * pm.llc_hit_cycles + r_u.misses * pm.mem_cycles
    _row("table4_merge_pr", (time.time() - t0) * 1e6,
         f"merge_speedup={t_u/t_m-1:+.1%} (paper PR: +40-52%)")


def kernels_microbench():
    """Kernel wall-time (interpret mode on CPU — correctness-path timing,
    not TPU perf; TPU perf is the roofline analysis)."""
    import jax
    import jax.numpy as jnp
    from repro.kernels.hot_gather import ops as hg

    key = jax.random.PRNGKey(0)
    prop = jax.random.normal(key, (1 << 15, 64))
    idx = jax.random.randint(key, (1 << 14,), 0, 1 << 13, dtype=jnp.int32)
    out = hg.hot_gather(prop, idx, hot_size=1 << 13)  # compile
    jax.block_until_ready(out)
    t0 = time.time()
    for _ in range(5):
        jax.block_until_ready(hg.hot_gather(prop, idx, hot_size=1 << 13))
    us = (time.time() - t0) / 5 * 1e6
    ref_t0 = time.time()
    for _ in range(5):
        jax.block_until_ready(jnp.take(prop, idx, axis=0))
    ref_us = (time.time() - ref_t0) / 5 * 1e6
    _row("kernel_hot_gather_interp", us, f"xla_take_us={ref_us:.0f}")


def roofline_summary():
    """Dry-run roofline digest (full table: EXPERIMENTS.md §Roofline)."""
    path = os.path.join("reports", "dryrun_final.json")
    if not os.path.exists(path):
        path = os.path.join("reports", "dryrun_baseline.json")
    if not os.path.exists(path):
        _row("roofline_summary", 0.0, "run launch/dryrun.py first")
        return
    with open(path) as f:
        recs = json.load(f)
    ok = [r for r in recs if r.get("status") == "ok"]
    _row("dryrun_cells_ok", 0.0, f"{len(ok)}/{len(recs)}")
    doms = {}
    for r in ok:
        doms[r["dominant"]] = doms.get(r["dominant"], 0) + 1
    _row("roofline_dominant_terms", 0.0, str(doms))


BENCHMARKS = {
    "table1": table1_skew,
    "fig2": fig2_access_classification,
    "table4": table4_array_merging,
    "fig5": fig5_miss_reduction,
    "fig6": fig6_speedup,
    "fig7": fig7_ablation,
    "fig8": fig8_pinning,
    "fig9": fig9_adversarial,
    "fig10a": fig10a_reordering,
    "fig10b": fig10b_grasp_generality,
    "fig11": fig11_table7_opt,
    "kernels": kernels_microbench,
    "roofline": roofline_summary,
}

FAST_AWARE = {"fig5", "fig6", "fig7", "fig8", "fig9", "fig10a", "fig10b",
              "fig11"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="PR-only subset of the app matrix")
    args = ap.parse_args()
    names = list(BENCHMARKS) if not args.only else args.only.split(",")
    print("name,us_per_call,derived")
    for n in names:
        fn = BENCHMARKS[n]
        if n in FAST_AWARE:
            fn(fast=args.fast)
        else:
            fn()


if __name__ == "__main__":
    main()
