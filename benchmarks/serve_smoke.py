"""`make serve-smoke` — end-to-end check of the repro.serve subsystem (CPU).

Runs the MIND serving engine on a zipf-skewed request stream three times
under the same device budget — GRASP two-region cache, unpinned
RRPV-only, unpinned LRU — and asserts the paper's claim holds at the
serving tier: the pinned-hot-region cache's hit rate beats both unpinned
baselines. A fourth run offers load far above the service budget with
deadlines attached and asserts shed-load keeps the served p99 bounded by
``deadline + one batch service time`` (throughput degrades, the tail does
not). Emits every snapshot to ``BENCH_serve.json``.

    PYTHONPATH=src python -m benchmarks.serve_smoke [--out BENCH_serve.json]

Non-tier-1: wired into scripts/verify.sh after the tier-1 steps.
"""
from __future__ import annotations

import argparse
import json

from repro.configs import base as cfgs
from repro.serve.cache import CacheConfig
from repro.serve.engine import StreamConfig, run_recsys_stream
from repro.serve.scheduler import SchedulerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--zipf-a", type=float, default=1.1)
    args = ap.parse_args(argv)

    cfg = cfgs.reduced(cfgs.get_arch("mind"))   # 1000 items, d=16
    row_bytes = cfg.embed_dim * 4
    budget = 128 * row_bytes                    # cache 128 of 1000 rows
    sched = SchedulerConfig(max_batch=8, max_queue=64)
    stream = StreamConfig(requests=args.requests, qps=500.0, candidates=16,
                          zipf_a=args.zipf_a, deadline_s=None)

    # --- hit-rate comparison under one capacity -----------------------
    runs = {}
    for name, hot_frac, policy in (
        ("grasp", 0.5, "rrpv"),
        ("baseline_rrpv", 0.0, "rrpv"),
        ("baseline_lru", 0.0, "lru"),
    ):
        cc = CacheConfig(budget_bytes=budget, hot_fraction=hot_frac,
                         policy=policy, tile_e=128)
        # fixed 1ms/batch virtual service => identical schedules, so the
        # three runs see the same reference stream
        runs[name] = run_recsys_stream(cfg, cc, sched, stream,
                                       service_time_s=1e-3)
        print(f"[serve-smoke] {name:14s} hit={runs[name]['hit_rate']:.2%} "
              f"(hot_size={runs[name]['config']['hot_size']} "
              f"cold_slots={runs[name]['config']['cold_slots']})")

    grasp = runs["grasp"]["hit_rate"]
    best_base = max(runs["baseline_rrpv"]["hit_rate"],
                    runs["baseline_lru"]["hit_rate"])
    assert runs["grasp"]["counters"]["completed"] == args.requests
    assert grasp > best_base, (
        f"GRASP cache hit rate {grasp:.2%} must beat the unpinned "
        f"baselines ({best_base:.2%}) at equal capacity")
    # and it must be a real cache, not a pass-through
    assert grasp > 0.5

    # --- overload: shed-load bounds the served tail -------------------
    deadline_s, service_s = 0.01, 2e-3
    over_sched = SchedulerConfig(max_batch=8, max_queue=64,
                                 default_deadline_s=deadline_s)
    over_stream = StreamConfig(requests=256, qps=20000.0, candidates=16,
                               zipf_a=args.zipf_a, deadline_s=deadline_s)
    over = run_recsys_stream(
        cfg, CacheConfig(budget_bytes=budget, hot_fraction=0.5, tile_e=128),
        over_sched, over_stream, service_time_s=service_s)
    c = over["counters"]
    dropped = c.get("shed", 0) + c.get("rejected", 0)
    p99 = over["latency"]["e2e"]["p99_s"]
    worst = over["latency"]["e2e"]["max_s"]  # exact (p99 is bucket-quantized)
    bound = deadline_s + service_s + 1e-9
    print(f"[serve-smoke] overload: served={c.get('completed', 0)}/256 "
          f"dropped={dropped} e2e_p99~{p99*1e3:.1f}ms "
          f"max={worst*1e3:.1f}ms (bound {bound*1e3:.1f}ms)")
    assert dropped > 0, "overload run must actually shed/reject load"
    assert c.get("completed", 0) > 0, "shed-load must not starve the engine"
    assert worst <= bound, (
        f"served worst-case e2e {worst*1e3:.1f}ms exceeds deadline+service "
        f"bound {bound*1e3:.1f}ms")

    out = {
        "hit_rate_comparison": runs,
        "overload": over,
        "verdict": {
            "grasp_hit_rate": grasp,
            "best_unpinned_hit_rate": best_base,
            "margin": grasp - best_base,
            "overload_p99_s": p99,
            "overload_max_e2e_s": worst,
            "overload_bound_s": bound,
        },
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, sort_keys=True)
    print(f"[serve-smoke] OK — GRASP beats unpinned by "
          f"{(grasp - best_base) * 1e2:.1f}pt; wrote {args.out}")
    return out


if __name__ == "__main__":
    main()  # assertion failure -> traceback + non-zero exit
