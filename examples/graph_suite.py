"""The paper's full application suite (Table III) on one dataset, with and
without skew-aware reordering + GRASP, including the hot-gather kernel path.

    PYTHONPATH=src python examples/graph_suite.py [--dataset tw] [--scale 13]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import apps
from repro.core import cachesim
from repro.core.reorder import reorder_ranks
from repro.graph import datasets, traces
from repro.graph.csr import apply_reorder, transpose
from repro.graph.generate import add_uniform_weights


def run_apps(g, label):
    dg = g.device()
    out_csr = transpose(add_uniform_weights(g, seed=1)).device()
    t = {}
    for name, fn in [
        ("pr", lambda: apps.pagerank(dg)),
        ("prd", lambda: apps.pagerank_delta(dg)),
        ("sssp", lambda: apps.sssp(out_csr, 0)),
        ("bc", lambda: apps.bc_single_source(transpose(g).device(), 0)[0]),
        ("radii", lambda: apps.radii_estimate(
            dg, jnp.arange(8, dtype=jnp.int32))[0]),
    ]:
        t0 = time.time()
        jax.block_until_ready(fn())
        t[name] = time.time() - t0
    print(f"  [{label}] " + "  ".join(f"{k}={v*1e3:.0f}ms" for k, v in t.items()))
    return t


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="tw")
    ap.add_argument("--scale", type=int, default=13)
    args = ap.parse_args()

    g = datasets.load(args.dataset, scale=args.scale)
    print(f"dataset {args.dataset}: {g.num_nodes} vertices {g.num_edges} edges")
    print("application runtimes (jit-compiled, includes compile on first):")
    run_apps(g, "original order")
    g2 = apply_reorder(g, reorder_ranks(g, "dbg"))
    run_apps(g2, "DBG reordered")

    print("LLC policy comparison per app (DBG + GRASP vs RRIP):")
    llc = datasets.scaled_llc_bytes(args.dataset, g2, elem_bytes=16)
    pm = cachesim.PerfModel()
    for app in ("pr", "prd", "sssp", "bc", "radii"):
        tr, _ = traces.generate_trace(g2, app, llc, max_records=600_000)
        rrip = cachesim.simulate(tr, "rrip", llc)
        grasp = cachesim.simulate(tr, "grasp", llc)
        print(f"  {app:6s} miss {rrip.miss_rate:.3f} -> {grasp.miss_rate:.3f} "
              f"speedup {pm.speedup(rrip, grasp)-1:+.1%}")


if __name__ == "__main__":
    main()
