"""Quickstart: the paper's pipeline in ~60 lines.

Generate a power-law graph, apply DBG reordering, run PageRank through the
vertex-centric engine (optionally through the GRASP hot-gather kernel), and
compare LLC policies on the resulting access trace.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import apps
from repro.core import cachesim, make_plan
from repro.core.reorder import reorder_ranks
from repro.graph import datasets, traces
from repro.graph.csr import apply_reorder


def main():
    # 1. a scaled stand-in for the paper's Twitter dataset
    g = datasets.load("tw", scale=13)
    print(f"graph: {g.num_nodes} vertices, {g.num_edges} edges")

    # 2. skew-aware reordering (DBG) — hot vertices become a prefix
    g2 = apply_reorder(g, reorder_ranks(g, "dbg"))

    # 3. run PageRank through the engine
    pr = np.asarray(apps.pagerank(g2.device()))
    print(f"pagerank: sum={pr.sum():.4f}, top vertex rank={pr.max():.2e}")

    # 4. GRASP: LLC trace of the iteration + policy comparison
    llc = datasets.scaled_llc_bytes("tw", g2, elem_bytes=16)
    tr, plan = traces.generate_trace(g2, "pr", llc)
    print(f"LLC={llc//1024}KB  hot region={plan.hot_size} vertices  "
          f"trace={tr.length} accesses")
    results = {}
    for policy in ("lru", "rrip", "grasp", "opt"):
        r = cachesim.simulate(tr, policy, llc)
        results[policy] = r
        print(f"  {policy:6s} miss rate {r.miss_rate:.3f}")
    pm = cachesim.PerfModel()
    print(f"GRASP speed-up over RRIP (proxy): "
          f"{pm.speedup(results['rrip'], results['grasp'])-1:+.1%}")

    # 5. the same gather through the VMEM-pinned Pallas kernel
    import jax.numpy as jnp
    from repro.kernels.hot_gather import ops as hg

    prop = jnp.asarray(np.random.default_rng(0).random((g2.num_nodes, 8)),
                       dtype=jnp.float32)
    kplan = make_plan(g2.num_nodes, 8 * 4, budget_bytes=llc)
    out = hg.hot_gather(prop, jnp.asarray(g2.indices), hot_size=kplan.hot_size)
    ref = jnp.take(prop, jnp.asarray(g2.indices), axis=0)
    print(f"hot_gather kernel max err vs reference: "
          f"{float(jnp.abs(out - ref).max()):.2e}")


if __name__ == "__main__":
    main()
