"""Batched LM serving example: prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve


if __name__ == "__main__":
    serve.main(["--arch", "starcoder2-7b", "--requests", "16",
                "--batch", "8", "--prefill", "64", "--decode", "32"])
