"""Batched LM serving example: prefill + decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py              # local loop
    PYTHONPATH=src python examples/serve_lm.py --gateway    # over sockets

``--gateway`` runs the same engine behind the repro.gateway front-end on
an ephemeral loopback port, sends a few generate requests through the
retrying client, prints the served continuations, and drains gracefully.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve


def gateway_demo():
    from repro.gateway import EnginePump, GatewayClient, GatewayServer
    from repro.serve.engine import LMServeEngine
    from repro.serve.scheduler import SchedulerConfig

    engine = LMServeEngine(
        arch="starcoder2-7b", smoke=True,
        sched_config=SchedulerConfig(max_batch=4, max_queue=32),
        prefill=16, decode=8)
    engine.warmup()
    with GatewayServer({"generate": EnginePump(engine, "generate")}) as srv:
        client = GatewayClient(srv.url, timeout_s=120.0)
        print(f"[example] gateway up at {srv.url}; "
              f"health={client.health()['status']}")
        for prompt in ([1, 2, 3], [7, 8, 9, 10], [42]):
            out = client.generate(prompt, timeout_s=120.0)
            print(f"[example] prompt={prompt} -> continuation={out}")
        tokens = client.metrics()["generate"]["counters"]["tokens_generated"]
        print(f"[example] served {tokens} tokens over HTTP; draining")


if __name__ == "__main__":
    if "--gateway" in sys.argv:
        gateway_demo()
    else:
        serve.main(["--arch", "starcoder2-7b", "--requests", "16",
                    "--batch", "8", "--prefill", "64", "--decode", "32"])
