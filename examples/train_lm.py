"""End-to-end LM training driver: a ~10M-param minitron-family model for a
few hundred steps with checkpointing and an injected failure (restart is
automatic and bit-exact).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses
import tempfile

import jax

from repro.configs import base as cfgs
from repro.data import pipeline
from repro.nn import transformer as tfm
from repro.train import ft as ft_mod
from repro.train import optimizer as opt_mod
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-at", type=int, default=120)
    args = ap.parse_args()

    # ~10M-param member of the minitron family (squared-ReLU, GQA)
    cfg = dataclasses.replace(
        cfgs.reduced(cfgs.get_arch("minitron-8b")),
        name="minitron-10m", n_layers=4, d_model=256, n_heads=8, n_kv=4,
        d_ff=1024, vocab=4096,
    )
    n_params = cfg.param_count()
    print(f"[example] training {cfg.name}: {n_params/1e6:.1f}M params")

    shape = cfgs.LMShape("ex", "train", seq_len=128, global_batch=16)
    ckpt = tempfile.mkdtemp(prefix="repro_lm_ckpt_")

    trainer = Trainer(
        loss_fn=lambda p, b: tfm.loss_fn(p, cfg, b),
        init_params=lambda: tfm.init(jax.random.PRNGKey(0), cfg),
        opt_cfg=opt_mod.OptConfig(name="adamw", lr=3e-4),
        tcfg=TrainerConfig(num_steps=args.steps, ckpt_dir=ckpt,
                           ckpt_every=50, log_every=20),
    )
    injector = ft_mod.FailureInjector(fail_at=(args.fail_at,))
    print(f"[example] failure injected at step {args.fail_at}; "
          f"checkpoints in {ckpt}")
    trainer.fit(pipeline.make_batch_fn("lm", cfg, shape, seed=0),
                injector=injector)
    losses = [h["loss"] for h in trainer.history]
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {args.steps} steps (1 restart)")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
