#!/usr/bin/env bash
# Tier-1 verify: the quick (non-slow) suite, then the 8-device GRASP
# exchange equivalence check in its own process (it must set XLA's host
# device count before jax initialises).
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -q -m "not slow"

XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python tests/helpers/grasp_gnn_equivalence.py

# 8-device bit-exactness of the pipelined (overlap=True) GRASP step vs the
# sequential exchange: identical loss AND params over multiple layers/steps
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python tests/helpers/grasp_pipeline_equivalence.py

# non-tier-1: serving subsystem end-to-end smoke (GRASP cache vs unpinned
# baselines + shed-load p99 bound); emits BENCH_serve.json
PYTHONPATH=src python -m benchmarks.serve_smoke --out BENCH_serve.json

# non-tier-1: gateway RPC front-end over loopback sockets (closed-loop hit
# rate vs the baseline BENCH_serve.json just wrote + 2x-overload tail
# bound + 503-retry recovery); bounded wall-clock, emits BENCH_gateway.json
PYTHONPATH=src timeout 600 python -m benchmarks.gateway_smoke --out BENCH_gateway.json

# non-tier-1: seeded fault injection over the same stack (conservation
# under crashes/resets, supervisor restarts == injected deaths, breaker
# 500-tail bound, same-seed determinism, warm-restart snapshot recovery);
# bounded wall-clock, emits BENCH_chaos.json
PYTHONPATH=src timeout 600 python -m benchmarks.chaos_smoke --out BENCH_chaos.json

# non-tier-1: tracked perf baseline (vectorized lookup >=3x the retained
# reference loop with bit-identical outputs/counters, pipelined dist step
# bit-exact vs sequential, hot_gather microbench); emits BENCH_perf.json
PYTHONPATH=src timeout 600 python -m benchmarks.perf_smoke --out BENCH_perf.json

echo "verify: OK"
