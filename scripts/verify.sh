#!/usr/bin/env bash
# Tier-1 verify: the quick (non-slow) suite, then the 8-device GRASP
# exchange equivalence check in its own process (it must set XLA's host
# device count before jax initialises).
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pytest -q -m "not slow"

XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python tests/helpers/grasp_gnn_equivalence.py

echo "verify: OK"
