"""Vertex-centric graph applications (paper Table III)."""
from repro.apps.engine import edge_map_pull, edge_map_push, EngineConfig  # noqa: F401
from repro.apps.pagerank import pagerank  # noqa: F401
from repro.apps.prdelta import pagerank_delta  # noqa: F401
from repro.apps.sssp import sssp  # noqa: F401
from repro.apps.bc import bc_single_source  # noqa: F401
from repro.apps.radii import radii_estimate  # noqa: F401
