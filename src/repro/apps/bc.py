"""Betweenness Centrality, Brandes single-root (paper Table III: BC).

Forward: BFS levels with shortest-path counts (sigma). Backward: dependency
accumulation level by level. Dense frontier masks; levels driven by
``lax.while_loop`` / ``fori_loop``.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.csr import DeviceCSR


@partial(jax.jit, static_argnames=("max_levels",))
def bc_single_source(g_out: DeviceCSR, source: int, max_levels: int = 64):
    """Returns (dependency scores delta, sigma, level) for one root.

    ``g_out``: out-edge CSR (``dst`` = edge source, ``indices`` = edge
    target — see engine.edge_map_push conventions).
    """
    n = g_out.num_nodes
    src_e, dst_e = g_out.dst, g_out.indices

    level = jnp.full((n,), -1, jnp.int32).at[source].set(0)
    sigma = jnp.zeros((n,), jnp.float32).at[source].set(1.0)

    def fwd_body(state):
        level, sigma, frontier, d = state
        # counts pushed from frontier to unvisited neighbours
        msg = jnp.where(jnp.take(frontier, src_e), jnp.take(sigma, src_e), 0.0)
        inc = jax.ops.segment_sum(msg, dst_e, num_segments=n)
        new = (inc > 0) & (level < 0)
        level = jnp.where(new, d + 1, level)
        sigma = sigma + jnp.where(new, inc, 0.0)
        return level, sigma, new, d + 1

    def fwd_cond(state):
        _, _, frontier, d = state
        return frontier.any() & (d < max_levels)

    frontier0 = jnp.zeros((n,), bool).at[source].set(True)
    level, sigma, _, depth = jax.lax.while_loop(
        fwd_cond, fwd_body, (level, sigma, frontier0, 0)
    )

    # Backward dependency accumulation, deepest level first:
    # delta[v] += sum_{w in succ(v)} sigma[v]/sigma[w] * (1 + delta[w])
    safe_sigma = jnp.maximum(sigma, 1.0)

    def bwd_body(i, delta):
        d = depth - i  # current successor level
        on_level = level == d
        coef = jnp.where(on_level, (1.0 + delta) / safe_sigma, 0.0)
        # edge (v=src_e -> w=dst_e) contributes when level[v]==d-1, level[w]==d
        msg = jnp.where(jnp.take(on_level, dst_e), jnp.take(coef, dst_e), 0.0)
        back = jax.ops.segment_sum(msg, src_e, num_segments=n)
        contrib = jnp.where(level == d - 1, back * sigma, 0.0)
        return delta + contrib

    delta = jax.lax.fori_loop(0, depth, bwd_body, jnp.zeros((n,), jnp.float32))
    return delta, sigma, level
