"""Ligra-like vertex-centric engine (paper Sec. II-B, IV-A).

Pull-based: every active destination gathers its in-neighbours' properties
and reduces them. Push-based: every active source scatters its property to
its out-neighbours. Both are expressed as edge-parallel segment reductions
(`jax.ops.segment_sum`/`segment_min`/...) over the COO-ordered edge list —
the TPU-native formulation of the paper's CSR traversal, and the layer the
``hot_gather`` Pallas kernel plugs into.

Direction switching (Ligra's push/pull heuristic) selects pull when the
active frontier covers more than ``switch_fraction`` of edges.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.graph.csr import DeviceCSR

Reducer = Callable[[jnp.ndarray, jnp.ndarray, int], jnp.ndarray]


def sum_reduce(data, seg, n):
    return jax.ops.segment_sum(data, seg, num_segments=n)


def min_reduce(data, seg, n):
    return jax.ops.segment_min(data, seg, num_segments=n)


def max_reduce(data, seg, n):
    return jax.ops.segment_max(data, seg, num_segments=n)


def or_reduce(data, seg, n):
    return jax.ops.segment_max(data.astype(jnp.uint32), seg, num_segments=n)


def gather_src(g: DeviceCSR, prop: jnp.ndarray, gather_impl: str = "jnp") -> jnp.ndarray:
    """prop[src] for every edge — THE hot path the paper targets.

    ``gather_impl='pallas_hot'`` routes through the two-tier VMEM-pinned
    kernel (``repro.kernels.hot_gather``); 'jnp' is the reference path used
    on CPU and inside the distributed step.
    """
    if gather_impl == "jnp":
        return jnp.take(prop, g.indices, axis=0)
    if gather_impl == "pallas_hot":
        from repro.kernels.hot_gather import ops as hot_ops

        return hot_ops.hot_gather(prop, g.indices)
    raise ValueError(gather_impl)


def edge_map_pull(
    g: DeviceCSR,
    prop: jnp.ndarray,
    active_dst: Optional[jnp.ndarray] = None,
    edge_fn: Optional[Callable] = None,
    reduce_fn: Reducer = sum_reduce,
    identity: float = 0.0,
    gather_impl: str = "jnp",
) -> jnp.ndarray:
    """For each vertex v: reduce(edge_fn(prop[src]) for src in in_nbrs(v)).

    ``active_dst`` masks destinations (inactive vertices receive
    ``identity``). Messages into inactive vertices are replaced by the
    identity before the reduction, matching Ligra's edgeMap semantics.
    """
    msgs = gather_src(g, prop, gather_impl)
    if edge_fn is not None:
        msgs = edge_fn(msgs, g)
    if active_dst is not None:
        mask = jnp.take(active_dst, g.dst)
        shape = (-1,) + (1,) * (msgs.ndim - 1)
        msgs = jnp.where(mask.reshape(shape), msgs, identity)
    out = reduce_fn(msgs, g.dst, g.num_nodes)
    return out


def edge_map_push(
    g: DeviceCSR,
    prop: jnp.ndarray,
    active_src: Optional[jnp.ndarray] = None,
    edge_fn: Optional[Callable] = None,
    reduce_fn: Reducer = min_reduce,
    identity: float = jnp.inf,
    gather_impl: str = "jnp",
) -> jnp.ndarray:
    """Push along out-edges. ``g`` must be the out-edge CSR (``transpose``):
    its ``indices`` are the pushing sources' targets' sources... i.e. for an
    out-CSR, ``indices`` = destination of each out-edge and ``dst`` = the
    pushing source. Messages flow source -> destination."""
    # In the out-edge CSR, g.dst enumerates sources and g.indices targets.
    msgs = jnp.take(prop, g.dst, axis=0)
    if edge_fn is not None:
        msgs = edge_fn(msgs, g)
    if active_src is not None:
        mask = jnp.take(active_src, g.dst)
        shape = (-1,) + (1,) * (msgs.ndim - 1)
        msgs = jnp.where(mask.reshape(shape), msgs, identity)
    return reduce_fn(msgs, g.indices, g.num_nodes)


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    switch_fraction: float = 0.05  # Ligra's |frontier edges| / |E| threshold
    gather_impl: str = "jnp"


def choose_direction(g: DeviceCSR, active: jnp.ndarray, cfg: EngineConfig) -> jnp.ndarray:
    """True -> pull (dense frontier), False -> push (sparse frontier)."""
    deg = jnp.diff(g.indptr)
    frontier_edges = jnp.sum(jnp.where(active, deg, 0))
    return frontier_edges > cfg.switch_fraction * g.indices.shape[0]
