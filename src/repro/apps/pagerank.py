"""PageRank (paper Table III: PR) — iterative pull-based."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.apps.engine import edge_map_pull, sum_reduce
from repro.graph.csr import DeviceCSR


@partial(jax.jit, static_argnames=("max_iters", "gather_impl"))
def pagerank(
    g: DeviceCSR,
    damping: float = 0.85,
    tol: float = 1e-6,
    max_iters: int = 100,
    gather_impl: str = "jnp",
) -> jnp.ndarray:
    n = g.num_nodes
    out_deg = jax.ops.segment_sum(
        jnp.ones_like(g.indices, dtype=jnp.float32), g.indices, num_segments=n
    )
    safe_deg = jnp.maximum(out_deg, 1.0)
    base = (1.0 - damping) / n

    def body(state):
        rank, _, it = state
        contrib = rank / safe_deg
        # dangling mass redistributed uniformly (matches networkx)
        dangling = jnp.sum(jnp.where(out_deg == 0, rank, 0.0))
        incoming = edge_map_pull(g, contrib, reduce_fn=sum_reduce,
                                 gather_impl=gather_impl)
        new_rank = base + damping * (incoming + dangling / n)
        err = jnp.sum(jnp.abs(new_rank - rank))
        return new_rank, err, it + 1

    def cond(state):
        _, err, it = state
        return (err > tol * n) & (it < max_iters)

    rank0 = jnp.full((n,), 1.0 / n, dtype=jnp.float32)
    rank, _, _ = jax.lax.while_loop(cond, body, (rank0, jnp.inf, 0))
    return rank
