"""PageRank-Delta (paper Table III: PRD).

Vertices are active in an iteration only when they have accumulated enough
change in their score — the pull-push Ligra variant the paper selects after
Property-Array merging (Table IV).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.apps.engine import edge_map_pull, sum_reduce
from repro.graph.csr import DeviceCSR


@partial(jax.jit, static_argnames=("max_iters", "gather_impl"))
def pagerank_delta(
    g: DeviceCSR,
    damping: float = 0.85,
    epsilon: float = 1e-5,
    max_iters: int = 100,
    gather_impl: str = "jnp",
) -> jnp.ndarray:
    n = g.num_nodes
    out_deg = jax.ops.segment_sum(
        jnp.ones_like(g.indices, dtype=jnp.float32), g.indices, num_segments=n
    )
    safe_deg = jnp.maximum(out_deg, 1.0)

    def body(state):
        rank, delta, active, it = state
        contrib = jnp.where(active, delta, 0.0) / safe_deg
        incoming = edge_map_pull(g, contrib, reduce_fn=sum_reduce,
                                 gather_impl=gather_impl)
        new_delta = damping * incoming
        new_rank = rank + new_delta
        new_active = jnp.abs(new_delta) > epsilon * jnp.abs(new_rank)
        return new_rank, new_delta, new_active, it + 1

    def cond(state):
        _, _, active, it = state
        return active.any() & (it < max_iters)

    rank0 = jnp.full((n,), (1.0 - damping) / n, dtype=jnp.float32)
    delta0 = rank0
    active0 = jnp.ones((n,), dtype=bool)
    rank, _, _, _ = jax.lax.while_loop(cond, body, (rank0, delta0, active0, 0))
    return rank
