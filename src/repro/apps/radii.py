"""Radii Estimation via multiple parallel bit-BFS (paper Table III: Radii).

Runs K simultaneous BFS's from sampled roots using per-vertex K-bit visit
masks (Magnien et al.). A vertex's estimated radius is the last iteration
in which its mask changed — a lower bound on eccentricity.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.csr import DeviceCSR


@partial(jax.jit, static_argnames=("max_iters",))
def radii_estimate(
    g: DeviceCSR,
    sample_roots: jnp.ndarray,  # (K<=32,) int32 vertex ids
    max_iters: int = 64,
):
    """Returns (radii, visit_mask). ``g`` = in-edge CSR (pull traversal)."""
    n = g.num_nodes
    k = sample_roots.shape[0]
    bits = (jnp.uint32(1) << jnp.arange(k, dtype=jnp.uint32)).astype(jnp.uint32)
    mask0 = jnp.zeros((n,), jnp.uint32).at[sample_roots].set(bits)

    # Bitwise-OR has no segment primitive; decompose into K bit planes of
    # booleans, each reduced with segment_max, then repack. (E,K) -> (N,K).
    def or_pull(mask):
        nbr_bits = (jnp.take(mask, g.indices)[:, None] >> jnp.arange(k)) & 1
        agg = jax.ops.segment_max(
            nbr_bits.astype(jnp.uint32), g.dst, num_segments=n
        )
        return (agg << jnp.arange(k)).sum(axis=1).astype(jnp.uint32)

    def body(state):
        mask, radii, it, _ = state
        new_mask = mask | or_pull(mask)
        changed = new_mask != mask
        radii = jnp.where(changed, it + 1, radii)
        return new_mask, radii, it + 1, changed.any()

    def cond(state):
        _, _, it, changed = state
        return changed & (it < max_iters)

    radii0 = jnp.zeros((n,), jnp.int32)
    mask, radii, _, _ = jax.lax.while_loop(
        cond, body, (mask0, radii0, 0, jnp.bool_(True))
    )
    return radii, mask
