"""Single-Source Shortest Path via Bellman-Ford (paper Table III: SSSP).

Push-based (the paper notes SSSP spends its ROI in push iterations): active
sources relax their out-edges; a vertex joins the next frontier when its
distance improved.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.graph.csr import DeviceCSR

INF = jnp.float32(jnp.inf)


@partial(jax.jit, static_argnames=("max_iters",))
def sssp(
    g_out: DeviceCSR,
    source: int,
    max_iters: int = 10_000,
) -> jnp.ndarray:
    """``g_out`` is the out-edge CSR: ``g_out.dst`` = pushing source of each
    edge, ``g_out.indices`` = its target (see ``engine.edge_map_push``)."""
    n = g_out.num_nodes
    w = g_out.weights if g_out.weights is not None else jnp.ones_like(
        g_out.indices, dtype=jnp.float32
    )
    src_of_edge, dst_of_edge = g_out.dst, g_out.indices

    def body(state):
        dist, active, it = state
        cand = jnp.where(jnp.take(active, src_of_edge),
                         jnp.take(dist, src_of_edge) + w, INF)
        best = jax.ops.segment_min(cand, dst_of_edge, num_segments=n)
        improved = best < dist
        return jnp.minimum(dist, best), improved, it + 1

    def cond(state):
        _, active, it = state
        return active.any() & (it < max_iters)

    dist0 = jnp.full((n,), INF).at[source].set(0.0)
    active0 = jnp.zeros((n,), bool).at[source].set(True)
    dist, _, _ = jax.lax.while_loop(cond, body, (dist0, active0, 0))
    return dist
