"""repro.chaos — deterministic fault injection for the serving stack.

``inject`` wraps a serve engine (forward exceptions, latency spikes,
``next_batch`` pump crashes) and the gateway client (connection resets)
behind a seeded, replayable ``FaultSchedule``: every injection decision
is a pure function of ``(seed, fault kind, call index)``, so the same
schedule driven through the same workload produces an identical
``InjectionLog`` — which is exactly what `make chaos-smoke` asserts.
See ``benchmarks/chaos_smoke.py`` for the end-to-end harness and
``src/repro/gateway/README.md`` for the failure-modes table.
"""
from repro.chaos.inject import (
    ChaosClient,
    ChaosEngine,
    FaultSchedule,
    FaultSpec,
    InjectedFault,
    InjectionLog,
)

__all__ = [
    "ChaosClient",
    "ChaosEngine",
    "FaultSchedule",
    "FaultSpec",
    "InjectedFault",
    "InjectionLog",
]
