"""Seeded fault injection for the serve/gateway stack.

Chaos only earns its keep when a failure is *reproducible*: an assertion
that "the supervisor restarted every injected crash" is meaningless if
the injected crash count varies run to run. So every injection decision
here is a **pure function of (seed, fault kind, call index)** — no shared
RNG stream whose draw order would depend on thread interleaving. Two runs
of the same schedule over the same workload therefore produce identical
``InjectionLog``\\ s (the determinism check in ``benchmarks/chaos_smoke.py``),
and a specific failure can be replayed by seed alone.

Fault surfaces, one per layer the gateway must survive:

  ``forward_error``   ``ChaosEngine.forward`` raises ``InjectedFault``
                      — absorbed by the pump (batch fails, 500 on the
                      wire, breaker fodder).
  ``latency_spike``   ``ChaosEngine.forward`` sleeps ``latency_spike_s``
                      first — exercises deadlines/sheds and the wedge
                      watchdog margin.
  ``pump_crash``      the wrapped batcher's ``next_batch`` raises —
                      escapes the pump's forward try/except and KILLS the
                      pump thread; only the supervisor brings it back.
                      Decided per *non-empty claim attempt* (idle polls
                      don't consume indices), so crash counts don't
                      depend on how long the pump idled.
  ``conn_reset``      ``ChaosClient`` raises ``ConnectionResetError`` at
                      the transport hook — ``pre`` mode drops the request
                      before it is sent (pure transport fault), ``post``
                      mode sends it, discards the response, then resets —
                      the double-execution hazard the idempotency-key
                      dedupe exists for. Decided per POST attempt.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.gateway.client import GatewayClient


class InjectedFault(RuntimeError):
    """A fault raised on purpose by the chaos layer."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Per-kind injection rates (probability per decision point) + seed."""

    seed: int = 0
    forward_error_rate: float = 0.0
    latency_spike_rate: float = 0.0
    latency_spike_s: float = 0.05
    pump_crash_rate: float = 0.0
    conn_reset_rate: float = 0.0


# stable kind ids — part of the decision function, do not renumber
_KIND_ID = {"forward_error": 0, "latency_spike": 1,
            "pump_crash": 2, "conn_reset": 3}
_RATE_FIELD = {"forward_error": "forward_error_rate",
               "latency_spike": "latency_spike_rate",
               "pump_crash": "pump_crash_rate",
               "conn_reset": "conn_reset_rate"}


class InjectionLog:
    """Thread-safe ordered record of fired injections.

    Entries are ``(kind, index)``; ordering is normalized per kind (each
    kind's indices are strictly increasing by construction), so two runs
    of the same schedule compare equal with a plain ``==`` on
    ``entries()`` regardless of cross-kind thread interleaving.
    """

    def __init__(self) -> None:
        self._events: List[Tuple[str, int]] = []
        self._lock = threading.Lock()

    def record(self, kind: str, index: int) -> None:
        with self._lock:
            self._events.append((kind, index))

    def entries(self) -> List[Tuple[str, int]]:
        with self._lock:
            return sorted(self._events)

    def count(self, kind: str) -> int:
        with self._lock:
            return sum(1 for k, _ in self._events if k == kind)

    def summary(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for k, _ in self._events:
                out[k] = out.get(k, 0) + 1
            return out


class FaultSchedule:
    """Pure-function fault decisions + the log of what actually fired."""

    def __init__(self, spec: FaultSpec) -> None:
        self.spec = spec
        self.log = InjectionLog()

    def decide(self, kind: str, index: int) -> bool:
        """Would-fire decision for the ``index``-th event of ``kind`` —
        stateless and thread-safe; fired decisions land in ``log``."""
        rate = getattr(self.spec, _RATE_FIELD[kind])
        if rate <= 0.0:
            return False
        draw = np.random.default_rng(
            [self.spec.seed, _KIND_ID[kind], index]).random()
        if draw >= rate:
            return False
        self.log.record(kind, index)
        return True


class _ChaosBatcher:
    """Batcher proxy that turns scheduled ``pump_crash`` decisions into a
    raising ``next_batch`` — the exact silent-pump-death failure mode."""

    def __init__(self, inner, schedule: FaultSchedule) -> None:
        self._inner = inner
        self._schedule = schedule
        self._claims = itertools.count()

    def next_batch(self):
        # only non-empty claims consume decision indices: an idle pump
        # polling an empty queue must not advance the fault schedule
        if self._inner.depth > 0:
            i = next(self._claims)
            if self._schedule.decide("pump_crash", i):
                raise InjectedFault(f"chaos: pump crash (claim #{i})")
        return self._inner.next_batch()

    def __getattr__(self, name):
        return getattr(self._inner, name)


class ChaosEngine:
    """Engine wrapper injecting forward faults per the schedule.

    Presents the full ``_EngineBase`` surface of the wrapped engine
    (``batcher`` is proxied for crash injection, everything else passes
    through), so it drops into ``EnginePump``/``GatewayServer`` unchanged.
    """

    def __init__(self, engine, schedule: FaultSchedule) -> None:
        self._engine = engine
        self.schedule = schedule
        self.batcher = _ChaosBatcher(engine.batcher, schedule)
        self._forwards = itertools.count()

    def forward(self, payloads):
        i = next(self._forwards)
        if self.schedule.decide("latency_spike", i):
            time.sleep(self.schedule.spec.latency_spike_s)
        if self.schedule.decide("forward_error", i):
            raise InjectedFault(f"chaos: forward error (call #{i})")
        return self._engine.forward(payloads)

    def __getattr__(self, name):
        return getattr(self._engine, name)


class ChaosClient(GatewayClient):
    """Gateway client injecting connection resets at the transport hook.

    ``reset_mode="pre"`` resets before the request is sent (server never
    sees it — the retry is safe); ``"post"`` sends the request, lets the
    server execute it, then resets before the response is consumed — the
    retry *re-sends an already-executed request*, which is only safe
    because the client attaches an idempotency key and the server dedupes
    on it. Resets are decided per POST attempt index; GETs pass through
    untouched (health polls must not perturb the schedule).
    """

    def __init__(self, base_url: str, schedule: FaultSchedule,
                 reset_mode: str = "post", **kw) -> None:
        super().__init__(base_url, **kw)
        if reset_mode not in ("pre", "post"):
            raise ValueError(f"reset_mode {reset_mode!r}")
        self.schedule = schedule
        self.reset_mode = reset_mode
        self._posts = itertools.count()

    def _open(self, req, timeout):
        if req.data is None:
            return super()._open(req, timeout)
        i = next(self._posts)
        if not self.schedule.decide("conn_reset", i):
            return super()._open(req, timeout)
        if self.reset_mode == "pre":
            raise ConnectionResetError(f"chaos: reset before send (#{i})")
        super()._open(req, timeout)   # server executed; response discarded
        raise ConnectionResetError(f"chaos: reset before response (#{i})")
