"""Config system: architectures x input shapes (the 40 assigned cells).

``ARCHS`` maps arch id -> ArchSpec; ``SHAPES[family]`` maps shape id ->
ShapeSpec. ``reduced()`` produces the CPU-smoke-test variant of any arch.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


# ---------------------------------------------------------------------------
# Architecture configs
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    act: str = "silu"          # ffn activation
    gated: bool = True         # GLU-style ffn
    moe: Optional[MoECfg] = None
    rope_theta: float = 10000.0
    norm: str = "rmsnorm"
    remat: bool = True
    optimizer: str = "adamw"   # nemotron-340b uses adafactor (memory)
    microbatches: int = 8      # gradient-accumulation splits of global batch
    seq_shard: bool = False    # Megatron-SP activation sharding over model
    layer_groups: int = 1      # >1: sqrt-L nested-group remat (340B class)
    # GRASP tie-in: Zipf-ordered vocab embedding with hot-prefix replication
    grasp_vocab: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def family(self) -> str:
        return "lm"

    def param_count(self) -> int:
        d, l = self.d_model, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv * self.head_dim * 2
        if self.moe:
            ff_mats = 3 if self.gated else 2
            ff = self.moe.n_experts * ff_mats * d * self.d_ff + d * self.moe.n_experts
        else:
            ff_mats = 3 if self.gated else 2
            ff = ff_mats * d * self.d_ff
        return l * (attn + ff + 2 * d) + 2 * self.vocab * d

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        attn = d * self.n_heads * self.head_dim * 2 + d * self.n_kv * self.head_dim * 2
        ff_mats = 3 if self.gated else 2
        ff = self.moe.top_k * ff_mats * d * self.d_ff + d * self.moe.n_experts
        return l * (attn + ff + 2 * d) + 2 * self.vocab * d


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    kind: str            # egnn | nequip | gin | pna
    n_layers: int
    d_hidden: int
    d_out: int = 16
    # nequip extras
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    # pna extras
    aggregators: tuple = ("mean", "max", "min", "std")
    scalers: tuple = ("identity", "amplification", "attenuation")
    # gin
    eps_learnable: bool = True
    # GRASP: apply DBG reordering + hot/cold sharded exchange
    grasp: bool = True

    @property
    def family(self) -> str:
        return "gnn"


@dataclasses.dataclass(frozen=True)
class RecsysConfig:
    name: str
    embed_dim: int = 64
    n_interests: int = 4
    capsule_iters: int = 3
    n_items: int = 2_097_152   # 2^21: row-shardable across 512 chips
    hist_len: int = 50
    n_negatives: int = 4096
    d_hidden: int = 256
    grasp: bool = True   # popularity-ordered table + hot-prefix replication

    @property
    def family(self) -> str:
        return "recsys"


# ---------------------------------------------------------------------------
# Shape configs (per family, matching the assignment)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LMShape:
    name: str
    kind: str        # train | prefill | decode
    seq_len: int
    global_batch: int


@dataclasses.dataclass(frozen=True)
class GNNShape:
    name: str
    kind: str        # full_graph | minibatch | molecule
    n_nodes: int
    n_edges: int
    d_feat: int = 64
    batch_nodes: int = 0     # minibatch
    fanout: tuple = ()       # minibatch
    batch_graphs: int = 0    # molecule


@dataclasses.dataclass(frozen=True)
class RecsysShape:
    name: str
    kind: str        # train | serve | retrieval
    batch: int
    n_candidates: int = 0


LM_SHAPES = {
    "train_4k": LMShape("train_4k", "train", 4096, 256),
    "prefill_32k": LMShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": LMShape("decode_32k", "decode", 32768, 128),
    "long_500k": LMShape("long_500k", "decode", 524288, 1),
}

GNN_SHAPES = {
    "full_graph_sm": GNNShape("full_graph_sm", "full_graph", 2708, 10556, d_feat=1433),
    "minibatch_lg": GNNShape(
        "minibatch_lg", "minibatch", 232_965, 114_615_892,
        d_feat=602, batch_nodes=1024, fanout=(15, 10),
    ),
    "ogb_products": GNNShape("ogb_products", "full_graph", 2_449_029, 61_859_140, d_feat=100),
    "molecule": GNNShape("molecule", "molecule", 30, 64, d_feat=16, batch_graphs=128),
}

RECSYS_SHAPES = {
    "train_batch": RecsysShape("train_batch", "train", 65536),
    "serve_p99": RecsysShape("serve_p99", "serve", 512),
    "serve_bulk": RecsysShape("serve_bulk", "serve", 262144),
    "retrieval_cand": RecsysShape("retrieval_cand", "retrieval", 1, n_candidates=1_000_000),
}

SHAPES = {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}


# ---------------------------------------------------------------------------
# Registry (populated by per-arch modules via register())
# ---------------------------------------------------------------------------
ARCHS: dict = {}


def register(cfg):
    ARCHS[cfg.name] = cfg
    return cfg


def get_arch(name: str):
    if not ARCHS:
        load_all()
    return ARCHS[name]


def all_archs():
    if not ARCHS:
        load_all()
    return dict(ARCHS)


def load_all():
    """Import every per-arch config module (side-effect: register())."""
    from repro.configs import (  # noqa: F401
        moonshot_v1_16b_a3b,
        phi35_moe_42b_a6_6b,
        minitron_8b,
        starcoder2_7b,
        nemotron4_340b,
        egnn,
        nequip,
        gin_tu,
        pna,
        mind,
    )


# ---------------------------------------------------------------------------
# Reduced configs for CPU smoke tests
# ---------------------------------------------------------------------------
def reduced(cfg):
    """Small same-family variant: few layers/width, tiny vocab/tables."""
    if isinstance(cfg, LMConfig):
        return dataclasses.replace(
            cfg,
            name=cfg.name + "-smoke",
            n_layers=2,
            d_model=64,
            n_heads=4,
            n_kv=max(1, min(cfg.n_kv, 2)),
            d_ff=128,
            vocab=512,
            moe=MoECfg(4, min(cfg.moe.top_k, 2)) if cfg.moe else None,
            remat=False,
            microbatches=1,
            seq_shard=False,
        )
    if isinstance(cfg, GNNConfig):
        return dataclasses.replace(
            cfg, name=cfg.name + "-smoke", n_layers=2, d_hidden=16, n_rbf=4
        )
    if isinstance(cfg, RecsysConfig):
        return dataclasses.replace(
            cfg,
            name=cfg.name + "-smoke",
            embed_dim=16,
            n_items=1000,
            hist_len=8,
            n_negatives=32,
            d_hidden=32,
        )
    raise TypeError(type(cfg))
