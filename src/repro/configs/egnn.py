"""EGNN [arXiv:2102.09844] — E(n)-equivariant, 4 layers, d=64."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(name="egnn", kind="egnn", n_layers=4, d_hidden=64))
