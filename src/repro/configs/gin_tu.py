"""GIN [arXiv:1810.00826] — 5 layers, d=64, sum aggregator, learnable eps."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    name="gin-tu", kind="gin", n_layers=5, d_hidden=64, eps_learnable=True,
))
