"""The paper's own evaluation configuration (Tables III-VI)."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class PaperEvalConfig:
    apps: tuple = ("bc", "sssp", "pr", "prd", "radii")
    high_skew: tuple = ("lj", "pl", "tw", "kr", "sd")
    adversarial: tuple = ("fr", "uni")
    reorderings: tuple = ("identity", "sort", "hubsort", "dbg", "gorder_lite")
    hw_baseline: str = "rrip"
    schemes: tuple = ("ship_mem", "hawkeye", "leeway", "grasp")
    pin_schemes: tuple = ("pin_25", "pin_50", "pin_75", "pin_100")
    llc_ways: int = 16
    scale: int = 15          # log2 vertices of the scaled datasets


CONFIG = PaperEvalConfig()
