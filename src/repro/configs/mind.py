"""MIND [arXiv:1904.08030] — multi-interest retrieval, capsule routing."""
from repro.configs.base import RecsysConfig, register

CONFIG = register(RecsysConfig(
    name="mind", embed_dim=64, n_interests=4, capsule_iters=3,
))
