"""Minitron-8B (pruned Nemotron-4) [arXiv:2407.14679]."""
from repro.configs.base import LMConfig, register

CONFIG = register(LMConfig(
    name="minitron-8b",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8,
    d_ff=16384, vocab=256000,
    act="relu2", gated=False,   # nemotron family: squared-ReLU, no GLU
    grasp_vocab=True,
))
