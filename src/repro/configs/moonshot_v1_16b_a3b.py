"""Moonlight-16B-A3B (Kimi/Moonshot) [hf:moonshotai/Moonlight-16B-A3B]."""
from repro.configs.base import LMConfig, MoECfg, register

CONFIG = register(LMConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16,
    d_ff=1408, vocab=163840,
    act="silu", gated=True,
    moe=MoECfg(n_experts=64, top_k=6),
    grasp_vocab=True,
))
