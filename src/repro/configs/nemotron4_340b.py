"""Nemotron-4-340B [arXiv:2402.16819] — squared-ReLU, GQA kv=8.

Optimizer defaults to adafactor: Adam fp32 moments for 340B params do not
fit 16GB/chip HBM on a 256-chip pod (see DESIGN.md memory budget)."""
from repro.configs.base import LMConfig, register

CONFIG = register(LMConfig(
    name="nemotron-4-340b",
    n_layers=96, d_model=18432, n_heads=96, n_kv=8,
    d_ff=73728, vocab=256000,
    act="relu2", gated=False,
    optimizer="adafactor",
    microbatches=16,    # best measured config (EXPERIMENTS §Perf journey)
    seq_shard=True,     # activation stash sharded over model
    grasp_vocab=True,
))
