"""NequIP [arXiv:2101.03164] — O(3)-equivariant interatomic potential.

5 layers, d=32, l_max=2, 8 Bessel RBFs, 5A cutoff. Implemented as
NequIP-lite (restricted tensor-product path set — DESIGN.md)."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    name="nequip", kind="nequip", n_layers=5, d_hidden=32,
    l_max=2, n_rbf=8, cutoff=5.0,
))
