"""Phi-3.5-MoE-instruct (42B total / 6.6B active) [hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.configs.base import LMConfig, MoECfg, register

CONFIG = register(LMConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8,
    d_ff=6400, vocab=32064,
    act="silu", gated=True,
    moe=MoECfg(n_experts=16, top_k=2),
    norm="layernorm",
    grasp_vocab=True,
))
