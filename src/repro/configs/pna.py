"""PNA [arXiv:2004.05718] — 4 layers, d=75, mean/max/min/std x id/amp/atten."""
from repro.configs.base import GNNConfig, register

CONFIG = register(GNNConfig(
    name="pna", kind="pna", n_layers=4, d_hidden=75,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
))
