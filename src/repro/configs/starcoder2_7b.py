"""StarCoder2-7B [arXiv:2402.19173] — GQA kv=4, RoPE, GELU FFN."""
from repro.configs.base import LMConfig, register

CONFIG = register(LMConfig(
    name="starcoder2-7b",
    n_layers=32, d_model=4608, n_heads=36, n_kv=4,
    d_ff=18432, vocab=49152,
    act="gelu", gated=False,
    norm="layernorm",
    grasp_vocab=True,
))
