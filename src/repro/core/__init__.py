"""GRASP core: the paper's contribution.

hotset  — hot-vertex identification (Table I statistics)
reorder — skew-aware reordering (Sort / HubSort / DBG / Gorder-lite)
regions — ABR interface + High/Moderate/Low classification (Sec. III-A/B)
plan    — GraspPlan, the TPU-native residency plan
policies/cachesim — LLC replacement policies + trace-driven simulator
"""
from repro.core.hotset import hot_mask, skew_stats, reuse_degree  # noqa: F401
from repro.core.reorder import reorder_ranks, TECHNIQUES  # noqa: F401
from repro.core.regions import make_regions, HIGH, MODERATE, LOW, DEFAULT  # noqa: F401
from repro.core.plan import GraspPlan, make_plan  # noqa: F401
