"""Trace-driven set-associative LLC simulator.

The simulator is a single ``jax.lax.scan`` over the access trace with
vectorized per-set state, jitted once per (policy, geometry). This is what
lets the full paper evaluation matrix (apps x datasets x policies x
reorderings) run on CPU in minutes.

Outputs per run: hits/misses, and hit/miss counts split by GRASP Reuse
Hint — the latter reproduces the paper's Fig. 2 style access/miss
classification and validates that wins come from the Property Array.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.policies import POLICIES, CacheCfg, INF


@dataclasses.dataclass(frozen=True)
class Trace:
    """One application ROI's LLC access stream (host numpy arrays)."""

    line: np.ndarray    # (T,) int64 cache-line ids (global)
    hint: np.ndarray    # (T,) int8 GRASP 2-bit Reuse Hint
    pc: np.ndarray      # (T,) int32 synthetic PC signature
    region: np.ndarray  # (T,) int32 16KB-region signature (SHiP-MEM)
    nxt: np.ndarray     # (T,) int64 next access time of the same line (INF if none)

    @property
    def length(self) -> int:
        return int(self.line.shape[0])


def compute_next_use(line: np.ndarray) -> np.ndarray:
    """Vectorized next-occurrence times (Belady preprocessing)."""
    t = line.shape[0]
    order = np.lexsort((np.arange(t), line))
    sorted_line = line[order]
    nxt = np.full(t, int(INF), dtype=np.int64)
    same = sorted_line[1:] == sorted_line[:-1]
    nxt[order[:-1][same]] = order[1:][same]
    return nxt


def finalize_trace(line, hint, pc, region_bytes_shift: int = 14, line_bytes: int = 64) -> Trace:
    line = np.asarray(line, dtype=np.int64)
    region = (line * line_bytes) >> region_bytes_shift
    return Trace(
        line=line,
        hint=np.asarray(hint, dtype=np.int8),
        pc=np.asarray(pc, dtype=np.int32),
        region=region.astype(np.int32),
        nxt=compute_next_use(line),
    )


@dataclasses.dataclass(frozen=True)
class SimResult:
    policy: str
    accesses: int
    hits: int
    hits_by_hint: np.ndarray   # (4,)
    accesses_by_hint: np.ndarray

    @property
    def misses(self) -> int:
        return self.accesses - self.hits

    @property
    def miss_rate(self) -> float:
        return self.misses / max(self.accesses, 1)

    def misses_by_hint(self) -> np.ndarray:
        return self.accesses_by_hint - self.hits_by_hint


@partial(jax.jit, static_argnames=("policy", "num_sets", "ways", "n_pcs", "n_regions"))
def _simulate(trace_arrays, policy: str, num_sets: int, ways: int, n_pcs: int, n_regions: int):
    cfg = CacheCfg(num_sets=num_sets, ways=ways, n_pcs=n_pcs, n_regions=n_regions)
    init_fn, step_fn = POLICIES[policy]
    state = init_fn(cfg)

    def body(carry, x):
        st, hit_hint = carry
        st, hit = step_fn(cfg, st, x)
        hit_hint = hit_hint.at[x["hint"]].add(jnp.where(hit, 1, 0))
        return (st, hit_hint), None

    t = trace_arrays["line"].shape[0]
    xs = dict(
        line=trace_arrays["line"],
        hint=trace_arrays["hint"].astype(jnp.int32),
        pc=trace_arrays["pc"],
        region=trace_arrays["region"],
        nxt=trace_arrays["nxt"],
        t=jnp.arange(t, dtype=jnp.int32),
    )
    (state, hits_by_hint), _ = jax.lax.scan(
        body, (state, jnp.zeros((4,), jnp.int32)), xs
    )
    return hits_by_hint


def simulate(trace: Trace, policy: str, llc_bytes: int, ways: int = 16,
             line_bytes: int = 64) -> SimResult:
    """Run one policy over one trace. LLC geometry from byte size."""
    lines = llc_bytes // line_bytes
    num_sets = max(lines // ways, 1)
    assert num_sets & (num_sets - 1) == 0, "num_sets must be a power of two"
    n_pcs = int(trace.pc.max()) + 1
    n_regions = int(trace.region.max()) + 1
    arrays = dict(
        line=jnp.asarray(trace.line.astype(np.int32)),
        hint=jnp.asarray(trace.hint),
        pc=jnp.asarray(trace.pc),
        region=jnp.asarray(trace.region),
        nxt=jnp.asarray(np.minimum(trace.nxt, int(INF)).astype(np.int32)),
    )
    hits_by_hint = np.asarray(
        _simulate(arrays, policy, num_sets, ways, n_pcs, n_regions)
    )
    acc_by_hint = np.bincount(trace.hint, minlength=4).astype(np.int64)
    return SimResult(
        policy=policy,
        accesses=trace.length,
        hits=int(hits_by_hint.sum()),
        hits_by_hint=hits_by_hint,
        accesses_by_hint=acc_by_hint,
    )


# ---------------------------------------------------------------------------
# Speed-up proxy model (paper reports wall-clock speed-ups from a cycle
# simulator; we map miss-rate deltas through a memory-latency model).
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PerfModel:
    """t = t_compute + accesses*(hit*L_llc + miss*L_mem).

    ``mem_fraction`` calibrates how memory-bound the app is at the baseline
    (graph analytics: ~0.7-0.8 of time in memory stalls; this reproduces
    the paper's ~6.4% miss reduction -> ~5.2% speed-up ratio).
    """

    llc_hit_cycles: float = 30.0
    mem_cycles: float = 200.0
    mem_fraction: float = 0.75

    def runtime(self, base: SimResult, res: SimResult) -> float:
        def mem_time(r: SimResult) -> float:
            return r.hits * self.llc_hit_cycles + r.misses * self.mem_cycles

        base_mem = mem_time(base)
        compute = base_mem * (1.0 - self.mem_fraction) / self.mem_fraction
        return compute + mem_time(res)

    def speedup(self, base: SimResult, res: SimResult) -> float:
        return self.runtime(base, base) / self.runtime(base, res)
