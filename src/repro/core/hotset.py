"""Hot-vertex identification (paper Sec. II-A, Table I).

A vertex is *hot* when its degree is >= the average degree. For pull-based
computation reuse of Property[v] is proportional to v's **out**-degree; for
push-based it is the **in**-degree (paper Sec. II-C).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSR


@dataclasses.dataclass(frozen=True)
class SkewStats:
    """Reproduces a column of the paper's Table I."""

    hot_fraction: float       # % of vertices classified hot
    edge_coverage: float      # % of edges connected to hot vertices
    num_hot: int
    avg_degree: float


def hot_mask(degree: np.ndarray) -> np.ndarray:
    """Boolean mask: degree >= average degree (the paper's definition)."""
    avg = degree.mean()
    return degree >= avg


def skew_stats(degree: np.ndarray) -> SkewStats:
    mask = hot_mask(degree)
    total_edges = degree.sum()
    cov = float(degree[mask].sum() / max(total_edges, 1))
    return SkewStats(
        hot_fraction=float(mask.mean()),
        edge_coverage=cov,
        num_hot=int(mask.sum()),
        avg_degree=float(degree.mean()),
    )


def reuse_degree(g: CSR, direction: str = "pull") -> np.ndarray:
    """Degree that predicts Property-array reuse for a traversal direction."""
    if direction == "pull":
        return g.out_degree
    if direction == "push":
        return g.in_degree
    raise ValueError(f"unknown direction {direction!r}")
