"""GraspPlan — the compile-time residency plan (TPU adaptation of the ABRs).

On a TPU there is no transparent LLC; fast-memory residency is a *software*
decision. ``GraspPlan`` carries exactly the information the paper's ABRs +
classification logic provide, resolved at plan time:

  * ``hot_size``       number of leading Property-Array elements (after
                       skew-aware reordering) that fit the fast-memory
                       budget — the High Reuse Region.
  * ``moderate_size``  the next budget's worth — the Moderate Reuse Region.
  * element geometry   so byte bounds can be recovered for the LLC
                       simulator / trace generator.

The same plan object drives three tiers:
  1. the Pallas ``hot_gather``/``embedding_bag`` kernels (hot prefix pinned
     in VMEM, cold streamed from HBM),
  2. the distributed property exchange (hot prefix replicated across chips,
     cold partitioned — ``dist/collectives.py``),
  3. the LLC simulator's hint stream (faithful paper reproduction).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.regions import GraspRegions, make_regions

# v5e-class geometry. VMEM is the fast-memory tier for the kernel plan; a
# fraction is reserved for streaming buffers / activations.
VMEM_BYTES = 128 * 1024 * 1024
DEFAULT_VMEM_FRACTION = 0.5


def entries_for_budget(
    budget_bytes: int,
    elem_bytes: int,
    align: int = 1,
    max_entries: Optional[int] = None,
) -> int:
    """How many ``elem_bytes``-sized rows fit a fast-memory byte budget.

    The one bytes->entries conversion shared by every residency tier: the
    kernel plan (``make_plan``), the distributed hot-replica sizing
    (``dist.collectives.partition_spec_for``) and the serving cache
    (``serve.cache``). ``align`` rounds down to a multiple (tile-aligned
    hot blocks); ``max_entries`` clamps to the table length.
    """
    n = max(int(budget_bytes), 0) // max(int(elem_bytes), 1)
    if max_entries is not None:
        n = min(n, int(max_entries))
    if align > 1:
        n -= n % align
    return int(n)


@dataclasses.dataclass(frozen=True)
class GraspPlan:
    num_elems: int          # Property Array length (vertices / table rows)
    elem_bytes: int         # bytes per element (after array merging)
    hot_size: int           # elements in the High Reuse Region
    moderate_size: int      # elements in the Moderate Reuse Region
    budget_bytes: int       # fast-memory budget backing hot_size
    num_arrays: int = 1     # Property Arrays sharing the budget

    @property
    def enabled(self) -> bool:
        return self.hot_size > 0

    @property
    def cold_size(self) -> int:
        return self.num_elems - self.hot_size

    def regions(self) -> GraspRegions:
        """Byte-granular region view for the LLC simulator.

        The High Reuse Region covers exactly ``hot_size`` elements, which
        already embodies the paper's LLC_size / num_arrays division.
        """
        return make_regions(
            [(0, self.num_elems * self.elem_bytes)],
            llc_bytes=max(self.hot_size * self.elem_bytes, 1),
        )

    def classify_elem(self, idx: np.ndarray) -> np.ndarray:
        """0=hot, 1=moderate, 2=cold for element indices (range test)."""
        idx = np.asarray(idx)
        return np.where(
            idx < self.hot_size,
            0,
            np.where(idx < self.hot_size + self.moderate_size, 1, 2),
        ).astype(np.int8)


def make_plan(
    num_elems: int,
    elem_bytes: int,
    budget_bytes: Optional[int] = None,
    num_arrays: int = 1,
    align: int = 1,
) -> GraspPlan:
    """Size the High/Moderate regions from a fast-memory budget.

    ``align`` rounds hot_size down to a multiple (kernels want tile-aligned
    hot blocks). On no-skew inputs the plan is identical — robustness comes
    from the *policies* staying flexible, not from disabling the plan
    (paper Sec. V-B).
    """
    if budget_bytes is None:
        budget_bytes = int(VMEM_BYTES * DEFAULT_VMEM_FRACTION)
    per_array = budget_bytes // max(num_arrays, 1)
    hot = entries_for_budget(per_array, elem_bytes, align=align,
                             max_entries=num_elems)
    mod = min(per_array // elem_bytes, num_elems - hot)
    return GraspPlan(
        num_elems=int(num_elems),
        elem_bytes=int(elem_bytes),
        hot_size=int(hot),
        moderate_size=int(mod),
        budget_bytes=int(budget_bytes),
        num_arrays=int(num_arrays),
    )
