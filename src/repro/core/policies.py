"""LLC replacement policies (paper Secs. III-C, IV-C).

Each policy is a pair of pure functions usable inside ``jax.lax.scan``:

    init(cfg)              -> state dict of jnp arrays
    step(cfg, state, x)    -> (state, hit: bool)

``x`` is one trace record: ``line`` (cache-line id), ``hint`` (2-bit GRASP
Reuse Hint), ``pc`` (synthetic PC signature), ``region`` (16KB memory
region id, SHiP-MEM signature), ``nxt`` (time of next access to this line;
INF if none — used only by OPT and for Hawkeye's Belady training labels),
``t`` (current time).

Implemented schemes:
  lru           true LRU (baseline of paper Table VII / Fig. 11)
  rrip          DRRIP with set dueling (paper's high-performance baseline)
  rrip_hints    Fig. 7 ablation: RRIP + software hints steer the two RRIP
                insertion positions
  grasp_insert  Fig. 7 ablation: GRASP insertion policy only
  grasp         full GRASP per Table II (insertion + hit-promotion)
  ship_mem      SHiP-MEM [49]: region-signature hit predictor over RRIP
  hawkeye       Hawkeye-lite [26]: PC-classifier trained with *exact*
                Belady labels (favourable to Hawkeye; our reproduction of
                its failure mode is therefore conservative)
  leeway        Leeway-lite [10]: PC-indexed live-distance dead-block
                prediction over the base victim policy
  pin_X         XMem-style pinning, X% of ways reservable (X=25,50,75,100)
  opt           Belady's MIN with bypass (offline upper bound)

All RRIP-family policies use a 3-bit RRPV (paper Table II: insert values
0/6/7, max 7).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax.numpy as jnp

RRPV_MAX = 7          # 3-bit counter
RRPV_LONG = 6         # "near LRU" insertion (SRRIP long re-reference)
INF = jnp.int32(2**31 - 1)


@dataclasses.dataclass(frozen=True)
class CacheCfg:
    num_sets: int          # power of two
    ways: int
    n_pcs: int = 8
    n_regions: int = 4096
    duel_mod: int = 8      # leader-set stride for DRRIP set dueling
    psel_bits: int = 10
    brrip_throttle: int = 32   # 1/32 of BRRIP inserts use RRPV_LONG
    hawkeye_horizon_factor: int = 2  # Belady-label horizon = f*S*W

    @property
    def set_mask(self) -> int:
        return self.num_sets - 1

    @property
    def set_shift(self) -> int:
        return self.num_sets.bit_length() - 1

    @property
    def capacity_lines(self) -> int:
        return self.num_sets * self.ways


def _lookup(cfg: CacheCfg, tags, line):
    s = line & cfg.set_mask
    tag = line >> cfg.set_shift
    row = tags[s]
    hit_vec = row == tag
    hit = hit_vec.any()
    hway = jnp.argmax(hit_vec)
    return s, tag, hit, hway


def _rrip_victim(row_rrpv):
    """Vectorized SRRIP victim: age all ways to put >=1 at RRPV_MAX, pick first."""
    delta = jnp.maximum(RRPV_MAX - row_rrpv.max(), 0)
    aged = row_rrpv + delta
    victim = jnp.argmax(aged == RRPV_MAX)
    return victim, aged


# --------------------------------------------------------------------------
# LRU
# --------------------------------------------------------------------------
def lru_init(cfg: CacheCfg):
    return dict(
        tags=jnp.full((cfg.num_sets, cfg.ways), -1, jnp.int32),
        ts=jnp.full((cfg.num_sets, cfg.ways), -1, jnp.int32),
    )


def lru_step(cfg: CacheCfg, state, x):
    s, tag, hit, hway = _lookup(cfg, state["tags"], x["line"])
    victim = jnp.argmin(state["ts"][s])
    way = jnp.where(hit, hway, victim)
    return (
        dict(
            tags=state["tags"].at[s, way].set(tag),
            ts=state["ts"].at[s, way].set(x["t"]),
        ),
        hit,
    )


# --------------------------------------------------------------------------
# DRRIP base + the GRASP family (shared machinery, Table II semantics)
# --------------------------------------------------------------------------
def _drrip_init(cfg: CacheCfg):
    return dict(
        tags=jnp.full((cfg.num_sets, cfg.ways), -1, jnp.int32),
        rrpv=jnp.full((cfg.num_sets, cfg.ways), RRPV_MAX, jnp.int8),
        psel=jnp.int32(1 << (cfg.psel_bits - 1)),
        brrip_cnt=jnp.int32(0),
    )


def _drrip_insert_rrpv(cfg: CacheCfg, state, s):
    """DRRIP default insertion value for set ``s`` (paper Table II Default)."""
    sr_leader = (s % cfg.duel_mod) == 0
    br_leader = (s % cfg.duel_mod) == 1
    use_brrip = jnp.where(
        sr_leader,
        False,
        jnp.where(br_leader, True, state["psel"] >= (1 << (cfg.psel_bits - 1))),
    )
    brrip_val = jnp.where(
        state["brrip_cnt"] % cfg.brrip_throttle == 0, RRPV_LONG, RRPV_MAX
    )
    ins = jnp.where(use_brrip, brrip_val, RRPV_LONG).astype(jnp.int8)
    return ins, sr_leader, br_leader


def _drrip_family_step(cfg: CacheCfg, state, x, insert_fn, hit_fn):
    """Shared DRRIP skeleton. ``insert_fn(default_ins, hint)->rrpv`` and
    ``hit_fn(old_rrpv, hint)->rrpv`` specialize the policy (Table II)."""
    s, tag, hit, hway = _lookup(cfg, state["tags"], x["line"])
    row = state["rrpv"][s]

    default_ins, sr_leader, br_leader = _drrip_insert_rrpv(cfg, state, s)
    ins = insert_fn(default_ins, x["hint"])

    # miss path
    victim, aged = _rrip_victim(row)
    row_miss = aged.at[victim].set(ins)
    # hit path
    row_hit = row.at[hway].set(hit_fn(row[hway], x["hint"]))

    way = jnp.where(hit, hway, victim)
    new_row = jnp.where(hit, row_hit, row_miss)
    miss = ~hit
    psel = jnp.clip(
        state["psel"]
        + jnp.where(miss & sr_leader, 1, 0)
        - jnp.where(miss & br_leader, 1, 0),
        0,
        (1 << cfg.psel_bits) - 1,
    )
    return (
        dict(
            tags=state["tags"].at[s, way].set(tag),
            rrpv=state["rrpv"].at[s].set(new_row),
            psel=psel,
            brrip_cnt=state["brrip_cnt"] + jnp.where(miss, 1, 0),
        ),
        hit,
    )


def rrip_step(cfg, state, x):
    return _drrip_family_step(
        cfg,
        state,
        x,
        insert_fn=lambda d, h: d,                      # hints ignored
        hit_fn=lambda r, h: jnp.int8(0),               # hit promotion to MRU
    )


def rrip_hints_step(cfg, state, x):
    # Fig. 7 "RRIP+Hints": High-Reuse inserted near LRU (RRPV_LONG), all
    # other blocks at LRU (RRPV_MAX); hits unchanged from RRIP.
    return _drrip_family_step(
        cfg,
        state,
        x,
        insert_fn=lambda d, h: jnp.where(
            h == 3, d, jnp.where(h == 0, RRPV_LONG, RRPV_MAX)
        ).astype(jnp.int8),
        hit_fn=lambda r, h: jnp.int8(0),
    )


def _grasp_insert(default_ins, hint):
    # Table II insertion: High->0, Moderate->6, Low->7, Default->DRRIP.
    return jnp.where(
        hint == 0,
        0,
        jnp.where(hint == 1, RRPV_LONG, jnp.where(hint == 2, RRPV_MAX, default_ins)),
    ).astype(jnp.int8)


def grasp_insert_step(cfg, state, x):
    # Fig. 7 "GRASP (Insertion-Only)": GRASP insertion + RRIP hit policy.
    return _drrip_family_step(
        cfg, state, x, insert_fn=_grasp_insert, hit_fn=lambda r, h: jnp.int8(0)
    )


def grasp_step(cfg, state, x):
    # Full GRASP, Table II: High hit -> MRU; Moderate/Low hit -> gradual
    # promotion (decrement); Default hit -> MRU (base RRIP behaviour).
    def hit_fn(r, h):
        gradual = jnp.maximum(r - 1, 0).astype(jnp.int8)
        return jnp.where((h == 1) | (h == 2), gradual, jnp.int8(0))

    return _drrip_family_step(cfg, state, x, insert_fn=_grasp_insert, hit_fn=hit_fn)


# --------------------------------------------------------------------------
# SHiP-MEM: region-signature hit predictor (unlimited-entry table, paper IV-C)
# --------------------------------------------------------------------------
def ship_init(cfg: CacheCfg):
    st = _drrip_init(cfg)
    st.update(
        shct=jnp.full((cfg.n_regions,), 1, jnp.int8),  # 3-bit, weakly reused
        sig=jnp.zeros((cfg.num_sets, cfg.ways), jnp.int32),
        outcome=jnp.zeros((cfg.num_sets, cfg.ways), jnp.bool_),
    )
    return st


def ship_step(cfg: CacheCfg, state, x):
    s, tag, hit, hway = _lookup(cfg, state["tags"], x["line"])
    row = state["rrpv"][s]
    victim, aged = _rrip_victim(row)

    shct = state["shct"]
    # training: on hit mark outcome + strengthen signature of *this* region;
    # on eviction of a never-reused block, weaken the victim's signature.
    vic_sig = state["sig"][s, victim]
    vic_dead = ~state["outcome"][s, victim] & (state["tags"][s, victim] >= 0)
    shct = shct.at[x["region"]].add(jnp.where(hit, 1, 0))
    shct = shct.at[vic_sig].add(jnp.where(~hit & vic_dead, -1, 0))
    shct = jnp.clip(shct, 0, 7)

    # original SHiP insertion semantics: predicted-dead regions insert at
    # distant RRPV, everything else at the SRRIP long position (SHiP never
    # inserts at MRU — its win comes from filtering, not protection)
    ctr = shct[x["region"]]
    ins = jnp.where(ctr == 0, RRPV_MAX, RRPV_LONG).astype(jnp.int8)
    row_miss = aged.at[victim].set(ins)
    row_hit = row.at[hway].set(jnp.int8(0))

    way = jnp.where(hit, hway, victim)
    new_row = jnp.where(hit, row_hit, row_miss)
    return (
        dict(
            tags=state["tags"].at[s, way].set(tag),
            rrpv=state["rrpv"].at[s].set(new_row),
            psel=state["psel"],
            brrip_cnt=state["brrip_cnt"],
            shct=shct,
            sig=state["sig"].at[s, way].set(
                jnp.where(hit, state["sig"][s, hway], x["region"]).astype(jnp.int32)
            ),
            outcome=state["outcome"].at[s, way].set(hit),
        ),
        hit,
    )


# --------------------------------------------------------------------------
# Hawkeye-lite: PC classifier trained by Belady labels
# --------------------------------------------------------------------------
def hawkeye_init(cfg: CacheCfg):
    return dict(
        tags=jnp.full((cfg.num_sets, cfg.ways), -1, jnp.int32),
        rrpv=jnp.full((cfg.num_sets, cfg.ways), RRPV_MAX, jnp.int8),
        pctr=jnp.full((cfg.n_pcs,), 4, jnp.int8),  # 3-bit, weakly friendly
    )


def hawkeye_step(cfg: CacheCfg, state, x):
    s, tag, hit, hway = _lookup(cfg, state["tags"], x["line"])
    row = state["rrpv"][s]

    # Belady training label: would OPT have hit this line's next use?
    horizon = cfg.hawkeye_horizon_factor * cfg.capacity_lines
    friendly_label = (x["nxt"] - x["t"]) <= horizon
    pctr = jnp.clip(
        state["pctr"].at[x["pc"]].add(jnp.where(friendly_label, 1, -1)), 0, 7
    )

    friendly = state["pctr"][x["pc"]] >= 4
    ins = jnp.where(friendly, 0, RRPV_MAX).astype(jnp.int8)
    # Hawkeye pathology reproduced (paper Sec. V-A): a hit whose PC is
    # predicted cache-averse is *demoted* (eviction priority), not promoted.
    hit_val = jnp.where(friendly, 0, RRPV_MAX).astype(jnp.int8)

    victim, aged = _rrip_victim(row)
    row_miss = aged.at[victim].set(ins)
    row_hit = row.at[hway].set(hit_val)

    way = jnp.where(hit, hway, victim)
    new_row = jnp.where(hit, row_hit, row_miss)
    return (
        dict(
            tags=state["tags"].at[s, way].set(tag),
            rrpv=state["rrpv"].at[s].set(new_row),
            pctr=pctr,
        ),
        hit,
    )


# --------------------------------------------------------------------------
# Leeway-lite: PC-indexed live-distance dead-block prediction
# --------------------------------------------------------------------------
def leeway_init(cfg: CacheCfg):
    st = _drrip_init(cfg)  # Leeway rides the same DRRIP base as the baseline
    st.update(
        sig=jnp.zeros((cfg.num_sets, cfg.ways), jnp.int32),
        birth=jnp.zeros((cfg.num_sets, cfg.ways), jnp.int32),
        last_hit=jnp.zeros((cfg.num_sets, cfg.ways), jnp.int32),
        acc=jnp.zeros((cfg.num_sets,), jnp.int32),  # per-set access clock
        ld=jnp.zeros((cfg.n_pcs,), jnp.int32),      # live distance per PC
    )
    return st


def leeway_step(cfg: CacheCfg, state, x):
    s, tag, hit, hway = _lookup(cfg, state["tags"], x["line"])
    row = state["rrpv"][s]
    clock = state["acc"][s]

    # dead-block test: set-accesses since last hit exceed the PC's live
    # distance with a conservative margin (Leeway's variability-aware
    # policies keep it close to the base scheme when reuse is noisy —
    # paper Sec. V-A: max slowdown 2.1%).
    age = clock - state["last_hit"][s]
    ld_v = state["ld"][state["sig"][s]]
    dead = (ld_v > 0) & (age > 2 * ld_v + cfg.ways) & (state["tags"][s] >= 0)
    # predicted-dead blocks are demoted to distant-re-reference and compete
    # with natural RRPV_MAX candidates (gentler than immediate eviction —
    # this is what keeps Leeway near the base scheme under variability)
    row_d = jnp.where(dead, jnp.int8(RRPV_MAX), row)
    any_dead = dead.any()
    victim, aged = _rrip_victim(row_d)

    # LD training on eviction: observed live distance of the victim block
    obs = state["last_hit"][s, victim] - state["birth"][s, victim]
    vic_sig = state["sig"][s, victim]
    old_ld = state["ld"][vic_sig]
    # variability-aware update (Leeway's conservative policy): grow to the
    # observed max immediately; shrink only on small deviations — a large
    # downward deviation signals high reuse variance, so keep the old LD.
    low_var = obs * 2 >= old_ld
    new_ld = jnp.where(
        obs > old_ld, obs,
        jnp.where(low_var, old_ld - (old_ld - obs) // 16, old_ld),
    )
    ld = state["ld"].at[vic_sig].set(jnp.where(hit, old_ld, new_ld))

    default_ins, sr_leader, br_leader = _drrip_insert_rrpv(cfg, state, s)
    row_miss = aged.at[victim].set(default_ins)
    row_hit = row.at[hway].set(jnp.int8(0))
    way = jnp.where(hit, hway, victim)
    new_row = jnp.where(hit, row_hit, row_miss)
    miss = ~hit
    psel = jnp.clip(
        state["psel"]
        + jnp.where(miss & sr_leader, 1, 0)
        - jnp.where(miss & br_leader, 1, 0),
        0,
        (1 << cfg.psel_bits) - 1,
    )
    return (
        dict(
            tags=state["tags"].at[s, way].set(tag),
            rrpv=state["rrpv"].at[s].set(new_row),
            psel=psel,
            brrip_cnt=state["brrip_cnt"] + jnp.where(miss, 1, 0),
            sig=state["sig"].at[s, way].set(
                jnp.where(hit, state["sig"][s, hway], x["pc"]).astype(jnp.int32)
            ),
            birth=state["birth"]
            .at[s, way]
            .set(jnp.where(hit, state["birth"][s, hway], clock)),
            last_hit=state["last_hit"].at[s, way].set(clock),
            acc=state["acc"].at[s].add(1),
            ld=ld,
        ),
        hit,
    )


# --------------------------------------------------------------------------
# XMem-style pinning (PIN-X), driven by the GRASP High-Reuse classification
# --------------------------------------------------------------------------
def _pin_init(cfg: CacheCfg):
    st = _drrip_init(cfg)
    st["pinned"] = jnp.zeros((cfg.num_sets, cfg.ways), jnp.bool_)
    return st


def _pin_step(cfg: CacheCfg, state, x, quota_ways: int):
    s, tag, hit, hway = _lookup(cfg, state["tags"], x["line"])
    row = state["rrpv"][s]
    pinned_row = state["pinned"][s]

    default_ins, sr_leader, br_leader = _drrip_insert_rrpv(cfg, state, s)

    # victim among unpinned ways only (pinned blocks cannot be evicted)
    masked = jnp.where(pinned_row, jnp.int8(-1), row)
    have_unpinned = (~pinned_row).any()
    delta = jnp.maximum(RRPV_MAX - masked.max(), 0)
    aged = jnp.where(pinned_row, row, row + delta)
    victim = jnp.argmax(jnp.where(pinned_row, jnp.int8(-1), aged) == RRPV_MAX)

    want_pin = (x["hint"] == 0) & (pinned_row.sum() < quota_ways)
    bypass = ~hit & ~have_unpinned  # fully pinned set: cannot insert

    ins = jnp.where(want_pin, 0, default_ins).astype(jnp.int8)
    row_miss = aged.at[victim].set(ins)
    row_hit = row.at[hway].set(jnp.int8(0))

    way = jnp.where(hit, hway, victim)
    new_row = jnp.where(hit, row_hit, jnp.where(bypass, row, row_miss))
    new_tag_val = jnp.where(bypass & ~hit, state["tags"][s, way], tag)
    pin_new = jnp.where(
        hit,
        pinned_row,  # pin status persists across hits
        jnp.where(
            bypass, pinned_row, pinned_row.at[victim].set(want_pin)
        ),
    )
    miss = ~hit
    psel = jnp.clip(
        state["psel"]
        + jnp.where(miss & sr_leader, 1, 0)
        - jnp.where(miss & br_leader, 1, 0),
        0,
        (1 << cfg.psel_bits) - 1,
    )
    return (
        dict(
            tags=state["tags"].at[s, way].set(new_tag_val),
            rrpv=state["rrpv"].at[s].set(new_row),
            psel=psel,
            brrip_cnt=state["brrip_cnt"] + jnp.where(miss, 1, 0),
            pinned=state["pinned"].at[s].set(pin_new),
        ),
        hit,
    )


# --------------------------------------------------------------------------
# Belady OPT with bypass
# --------------------------------------------------------------------------
def opt_init(cfg: CacheCfg):
    return dict(
        tags=jnp.full((cfg.num_sets, cfg.ways), -1, jnp.int32),
        nxt=jnp.full((cfg.num_sets, cfg.ways), INF, jnp.int32),
    )


def opt_step(cfg: CacheCfg, state, x):
    s, tag, hit, hway = _lookup(cfg, state["tags"], x["line"])
    nrow = state["nxt"][s]
    victim = jnp.argmax(nrow)
    bypass = ~hit & (x["nxt"] >= nrow.max())
    way = jnp.where(hit, hway, victim)
    do_write = hit | ~bypass
    tags = state["tags"].at[s, way].set(
        jnp.where(do_write, tag, state["tags"][s, way])
    )
    nxt = state["nxt"].at[s, way].set(
        jnp.where(do_write, x["nxt"], state["nxt"][s, way])
    )
    return dict(tags=tags, nxt=nxt), hit


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
POLICIES: Dict[str, Tuple[Callable, Callable]] = {
    "lru": (lru_init, lru_step),
    "rrip": (_drrip_init, rrip_step),
    "rrip_hints": (_drrip_init, rrip_hints_step),
    "grasp_insert": (_drrip_init, grasp_insert_step),
    "grasp": (_drrip_init, grasp_step),
    "ship_mem": (ship_init, ship_step),
    "hawkeye": (hawkeye_init, hawkeye_step),
    "leeway": (leeway_init, leeway_step),
    "opt": (opt_init, opt_step),
}

for _x in (25, 50, 75, 100):
    def _mk(xval):
        def step(cfg, state, x):
            quota = max(1, round(cfg.ways * xval / 100))
            return _pin_step(cfg, state, x, quota)
        return step
    POLICIES[f"pin_{_x}"] = (_pin_init, _mk(_x))
