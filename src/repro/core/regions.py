"""GRASP software-hardware interface + classification logic (paper Sec. III-A/B).

An :class:`ABR` (Address Bound Registers) pair delimits one Property Array.
GRASP labels the first LLC-sized chunk the *High Reuse Region*, the next
LLC-sized chunk the *Moderate Reuse Region*; everything else in the array is
*Low-Reuse* and any address outside all registered arrays is *Default*
(domain-specialized management disabled). When an application registers K
Property Arrays, each array's region budget is LLC_size / K (paper: "GRASP
divides LLC-size by the number of Property Arrays").

Classification is a pure range test — evaluated here both as a host-side
numpy function (for trace generation) and a jnp function (for jitted use in
kernels/collectives).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

# 2-bit Reuse Hint encoding (paper Fig. 4)
HIGH, MODERATE, LOW, DEFAULT = np.int8(0), np.int8(1), np.int8(2), np.int8(3)


@dataclasses.dataclass(frozen=True)
class ABR:
    """One Property Array's bounds (virtual-address analogue: byte offsets)."""

    start: int  # inclusive
    end: int    # exclusive


@dataclasses.dataclass(frozen=True)
class GraspRegions:
    """Derived High/Moderate region bounds for a set of Property Arrays."""

    abrs: tuple[ABR, ...]
    llc_bytes: int

    @property
    def region_bytes(self) -> int:
        return self.llc_bytes // max(len(self.abrs), 1)

    def bounds(self, i: int) -> tuple[int, int, int, int]:
        """(high_lo, high_hi, mod_hi, array_hi) byte offsets of array i."""
        a = self.abrs[i]
        rb = self.region_bytes
        high_hi = min(a.start + rb, a.end)
        mod_hi = min(high_hi + rb, a.end)
        return a.start, high_hi, mod_hi, a.end

    def classify(self, addr: np.ndarray) -> np.ndarray:
        """Vectorized host-side classification of byte addresses -> hints."""
        addr = np.asarray(addr)
        hint = np.full(addr.shape, DEFAULT, dtype=np.int8)
        for i in range(len(self.abrs)):
            lo, high_hi, mod_hi, hi = self.bounds(i)
            inside = (addr >= lo) & (addr < hi)
            hint = np.where(inside & (addr < high_hi), HIGH, hint)
            hint = np.where(inside & (addr >= high_hi) & (addr < mod_hi), MODERATE, hint)
            hint = np.where(inside & (addr >= mod_hi), LOW, hint)
        return hint

    def classify_jnp(self, addr: jnp.ndarray) -> jnp.ndarray:
        hint = jnp.full(addr.shape, int(DEFAULT), dtype=jnp.int8)
        for i in range(len(self.abrs)):
            lo, high_hi, mod_hi, hi = self.bounds(i)
            inside = (addr >= lo) & (addr < hi)
            hint = jnp.where(inside & (addr < high_hi), int(HIGH), hint)
            hint = jnp.where(
                inside & (addr >= high_hi) & (addr < mod_hi), int(MODERATE), hint
            )
            hint = jnp.where(inside & (addr >= mod_hi), int(LOW), hint)
        return hint


def make_regions(array_bounds: Sequence[tuple[int, int]], llc_bytes: int) -> GraspRegions:
    return GraspRegions(
        abrs=tuple(ABR(lo, hi) for lo, hi in array_bounds),
        llc_bytes=int(llc_bytes),
    )
