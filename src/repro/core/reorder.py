"""Skew-aware vertex reordering (paper Sec. II-E, IV-B).

Every technique returns ``rank`` with ``rank[old_id] = new_id`` such that
hotter vertices receive smaller new ids — after reordering the hottest
vertices occupy a contiguous *prefix* of the Property Array, which is what
GRASP's range-test classification relies on (paper Fig. 3(a)).

Implemented techniques (paper Sec. IV-B):
  - ``sort``     full degree-descending sort.
  - ``hubsort``  HubSort [Zhang et al.]: sorts only hot vertices into the
                 prefix; cold vertices keep their relative order.
  - ``dbg``      Degree-Based Grouping [Faldu et al.]: coarse degree
                 buckets, hottest bucket first, original order preserved
                 within each bucket (structure-preserving).
  - ``gorder_lite`` a BFS locality ordering followed by a DBG pass — the
                 paper's recipe for making Gorder GRASP-compatible
                 (Sec. V-C applies DBG *after* Gorder).
  - ``identity`` no reordering (baseline).
"""
from __future__ import annotations

from typing import Callable, Dict

import numpy as np

from repro.graph.csr import CSR
from repro.core.hotset import hot_mask, reuse_degree


def identity_order(degree: np.ndarray) -> np.ndarray:
    return np.arange(degree.shape[0], dtype=np.int64)


def sort_order(degree: np.ndarray) -> np.ndarray:
    """Descending-degree sort (stable so equal degrees keep structure)."""
    new_of_old = np.argsort(-degree, kind="stable")
    rank = np.empty_like(new_of_old)
    rank[new_of_old] = np.arange(degree.shape[0], dtype=np.int64)
    return rank


def hubsort_order(degree: np.ndarray) -> np.ndarray:
    n = degree.shape[0]
    hot = hot_mask(degree)
    hot_ids = np.nonzero(hot)[0]
    cold_ids = np.nonzero(~hot)[0]
    hot_sorted = hot_ids[np.argsort(-degree[hot_ids], kind="stable")]
    order = np.concatenate([hot_sorted, cold_ids])  # cold keeps orig order
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n, dtype=np.int64)
    return rank


def dbg_order(degree: np.ndarray, num_groups: int = 8) -> np.ndarray:
    """Degree-Based Grouping: log2-spaced degree buckets around the mean.

    Group boundary k holds vertices with degree in [avg * 2^(k-1), avg * 2^k);
    groups are laid out hottest-first; *within* a group the original vertex
    order is preserved, retaining community structure.
    """
    n = degree.shape[0]
    avg = max(degree.mean(), 1e-9)
    # group 0 = hottest (degree >= avg * 2^(num_groups-2)) ... last = coldest
    ratio = degree / avg
    with np.errstate(divide="ignore"):
        level = np.floor(np.log2(np.maximum(ratio, 1e-9))).astype(np.int64)
    # level >= 0 means degree >= avg (hot); clamp into num_groups buckets
    group = np.clip((num_groups - 2) - level, 0, num_groups - 1)
    order = np.argsort(group, kind="stable")  # stable keeps in-group order
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n, dtype=np.int64)
    return rank


def _bfs_order(g: CSR) -> np.ndarray:
    """Locality ordering: BFS from the highest-degree vertex (per component)."""
    n = g.num_nodes
    # BFS over the union of in/out adjacency so direction doesn't matter.
    deg = g.in_degree + g.out_degree
    visited = np.zeros(n, dtype=bool)
    order = np.empty(n, dtype=np.int64)
    pos = 0
    indptr, indices = g.indptr, g.indices
    dst = g.dst_ids()
    # out-adjacency built once (src -> list of dst) for forward traversal
    out_order = np.argsort(indices, kind="stable")
    out_dst = dst[out_order]
    out_counts = np.bincount(indices, minlength=n)
    out_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(out_counts, out=out_indptr[1:])
    seeds = np.argsort(-deg, kind="stable")
    si = 0
    while pos < n:
        while si < n and visited[seeds[si]]:
            si += 1
        frontier = np.array([seeds[si]], dtype=np.int64)
        visited[seeds[si]] = True
        while frontier.size:
            order[pos : pos + frontier.size] = frontier
            pos += frontier.size
            nbrs = []
            for v in frontier:
                nbrs.append(indices[indptr[v] : indptr[v + 1]])
                nbrs.append(out_dst[out_indptr[v] : out_indptr[v + 1]])
            if nbrs:
                cand = np.unique(np.concatenate(nbrs))
                cand = cand[~visited[cand]]
            else:
                cand = np.empty(0, dtype=np.int64)
            visited[cand] = True
            frontier = cand
    return order


def gorder_lite_order(g: CSR, degree: np.ndarray) -> np.ndarray:
    """BFS locality order + DBG pass (paper Sec. V-C Gorder+DBG recipe)."""
    bfs = _bfs_order(g)  # new -> old
    rank_bfs = np.empty_like(bfs)
    rank_bfs[bfs] = np.arange(g.num_nodes, dtype=np.int64)
    # DBG applied in BFS order: stable sort by degree bucket of the
    # BFS-reordered vertices, keeping BFS order within buckets.
    deg_in_bfs_order = degree[bfs]
    rank_dbg = dbg_order(deg_in_bfs_order)
    # old v -> bfs slot rank_bfs[v] -> final slot rank_dbg[rank_bfs[v]]
    return rank_dbg[rank_bfs]


def reorder_ranks(g: CSR, technique: str, direction: str = "pull") -> np.ndarray:
    """rank[old_id] = new_id for the requested technique."""
    degree = reuse_degree(g, direction)
    if technique == "identity":
        return identity_order(degree)
    if technique == "sort":
        return sort_order(degree)
    if technique == "hubsort":
        return hubsort_order(degree)
    if technique == "dbg":
        return dbg_order(degree)
    if technique == "gorder_lite":
        return gorder_lite_order(g, degree)
    raise ValueError(f"unknown reordering technique {technique!r}")


TECHNIQUES = ("identity", "sort", "hubsort", "dbg", "gorder_lite")


def reorder_cost_model(technique: str, num_nodes: int, num_edges: int) -> float:
    """Relative reordering cost in 'edge traversals' (paper Fig. 10(a)).

    Skew-aware techniques are O(N log N) or O(N); Gorder is orders of
    magnitude costlier (paper: avg −85.4% net speed-up). Used by the
    benchmark that reproduces Fig. 10(a) net speed-ups.
    """
    n, m = float(num_nodes), float(num_edges)
    return {
        "identity": 0.0,
        "sort": 2.0 * n * np.log2(max(n, 2)) / m,        # full sort
        "hubsort": 0.5 * n * np.log2(max(n, 2)) / m,     # sorts hot only
        "dbg": 2.0 * n / m,                              # linear pass
        "gorder_lite": 400.0,                            # Gorder: >>runtime
    }[technique]
