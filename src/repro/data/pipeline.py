"""Synthetic, sharding-aware data pipeline.

Batches mirror the real modality statistics that matter to the system under
test: token ids and recsys item ids are Zipf-distributed (the skew GRASP
exploits), GNN batches come from RMAT graphs or the fanout sampler. The
iterator supports background prefetch (double buffering) and deterministic
seeding for the fault-tolerance restart tests.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import GNNConfig, GNNShape, LMConfig, LMShape, RecsysConfig, RecsysShape


def zipf_ids(rng: np.random.Generator, shape, vocab: int, a: float = 1.2) -> np.ndarray:
    """Zipf-distributed ids in [0, vocab) — id 0 is the hottest (the
    popularity-ordered layout the GRASP plan expects)."""
    raw = rng.zipf(a, size=shape)
    return np.minimum(raw - 1, vocab - 1).astype(np.int32)


def lm_batch(rng: np.random.Generator, cfg: LMConfig, batch: int, seq: int) -> Dict:
    tokens = zipf_ids(rng, (batch, seq + 1), cfg.vocab)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:].astype(np.int32)}


def recsys_batch(rng: np.random.Generator, cfg: RecsysConfig, shape: RecsysShape) -> Dict:
    b = shape.batch
    hist = zipf_ids(rng, (b, cfg.hist_len), cfg.n_items)
    hist_mask = rng.random((b, cfg.hist_len)) < 0.9
    out = {"hist": hist, "hist_mask": hist_mask}
    if shape.kind == "train":
        out["target"] = zipf_ids(rng, (b,), cfg.n_items)
        out["negatives"] = rng.integers(0, cfg.n_items, cfg.n_negatives).astype(np.int32)
    elif shape.kind == "serve":
        out["candidates"] = rng.integers(0, cfg.n_items, (b, 64)).astype(np.int32)
    elif shape.kind == "retrieval":
        out["candidates"] = rng.integers(0, cfg.n_items, shape.n_candidates).astype(np.int32)
    return out


def gnn_full_graph_batch(rng: np.random.Generator, shape: GNNShape,
                         n_classes: int = 47, scale_override: Optional[int] = None) -> Dict:
    """Synthetic stand-in with the requested node/edge counts (RMAT skew).
    ``scale_override`` shrinks for smoke tests."""
    from repro.graph import generate

    if scale_override is not None:
        n = 1 << scale_override
        e = n * max(shape.n_edges // max(shape.n_nodes, 1), 2)
    else:
        n, e = shape.n_nodes, shape.n_edges
    g = generate.rmat(int(np.ceil(np.log2(n))), max(e // (1 << int(np.ceil(np.log2(n)))), 1),
                      seed=int(rng.integers(0, 2**31)))
    nn_, ee = g.num_nodes, g.num_edges
    pad = (-ee) % 512  # shardability padding, matches launch/steps._pad_to
    src = np.pad(g.indices.astype(np.int32), (0, pad))
    dst = np.pad(g.dst_ids().astype(np.int32), (0, pad))
    emask = np.pad(np.ones(ee, bool), (0, pad))
    ee += pad
    return {
        "x": rng.standard_normal((nn_, shape.d_feat)).astype(np.float32),
        "src": src,
        "dst": dst,
        "emask": emask,
        "labels": rng.integers(0, n_classes, nn_).astype(np.int32),
        "coords": rng.standard_normal((nn_, 3)).astype(np.float32),
        "species": rng.integers(0, 8, nn_).astype(np.int32),
    }


def gnn_molecule_batch(rng: np.random.Generator, shape: GNNShape) -> Dict:
    """Batched small molecules, flattened with graph_id segments."""
    bg, n, e = shape.batch_graphs, shape.n_nodes, shape.n_edges
    nn_ = bg * n
    coords = rng.standard_normal((nn_, 3)).astype(np.float32) * 2.0
    src = np.concatenate([rng.integers(0, n, e) + i * n for i in range(bg)])
    dst = np.concatenate([rng.integers(0, n, e) + i * n for i in range(bg)])
    keep = src != dst
    return {
        "x": rng.standard_normal((nn_, shape.d_feat)).astype(np.float32),
        "src": src.astype(np.int32),
        "dst": dst.astype(np.int32),
        "emask": keep,
        "coords": coords,
        "species": rng.integers(0, 8, nn_).astype(np.int32),
        "graph_id": np.repeat(np.arange(bg), n).astype(np.int32),
        "labels": rng.standard_normal(bg).astype(np.float32),
    }


def gnn_minibatch(rng: np.random.Generator, g, shape: GNNShape, d_feat: int,
                  n_classes: int = 47) -> Dict:
    from repro.graph import sampler

    seeds = rng.integers(0, g.num_nodes, shape.batch_nodes)
    blocks = sampler.sample_blocks(g, seeds, tuple(shape.fanout), rng)
    return {
        "x": rng.standard_normal((blocks.n_sub, d_feat)).astype(np.float32),
        "src": blocks.src,
        "dst": blocks.dst,
        "emask": blocks.emask,
        "labels": rng.integers(0, n_classes, shape.batch_nodes).astype(np.int32),
        "seeds": blocks.seeds_local,
        "coords": rng.standard_normal((blocks.n_sub, 3)).astype(np.float32),
        "species": rng.integers(0, 8, blocks.n_sub).astype(np.int32),
    }


class Prefetcher:
    """Background-thread double buffering around a batch function."""

    def __init__(self, make_batch: Callable[[int], Dict], depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._make = make_batch
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = 0
        while not self._stop.is_set():
            try:
                self._q.put(self._make(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __next__(self):
        return self._q.get()

    def __iter__(self) -> Iterator[Dict]:
        return self

    def close(self):
        self._stop.set()


def batches(kind: str, cfg, shape, seed: int = 0) -> Iterator[Dict]:
    """Deterministic batch stream (seeded per step — FT restarts replay)."""
    step = 0
    while True:
        rng = np.random.default_rng((seed, step))
        if kind == "lm":
            yield lm_batch(rng, cfg, shape.global_batch, shape.seq_len)
        elif kind == "recsys":
            yield recsys_batch(rng, cfg, shape)
        else:
            raise ValueError(kind)
        step += 1


def make_batch_fn(kind: str, cfg, shape, seed: int = 0) -> Callable[[int], Dict]:
    """Deterministic step->batch function (FT restarts replay bit-exact)."""
    def fn(step: int) -> Dict:
        rng = np.random.default_rng((seed, step))
        if kind == "lm":
            return lm_batch(rng, cfg, shape.global_batch, shape.seq_len)
        if kind == "recsys":
            return recsys_batch(rng, cfg, shape)
        raise ValueError(kind)

    return fn
