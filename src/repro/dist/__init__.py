"""Distributed subsystem: sharding vocabulary + GRASP-aware collectives.

``repro.dist.sharding`` is the PartitionSpec/NamedSharding vocabulary used
by the launch layer (steps/dryrun/train/serve); ``repro.dist.collectives``
is the GRASP distributed exchange — hot-prefix replication with a bounded
cold halo (paper Table I lifted to the partition tier).

Importing this package also installs two tiny jax compatibility aliases so
the launch code and tests run on the older jax pinned in this container:
``jax.set_mesh`` (context-manager form) and ``jax.shard_map``. Both are
no-ops on jax versions that already provide them.
"""
import contextlib

import jax

if not hasattr(jax, "shard_map"):
    from jax.experimental.shard_map import shard_map as _shard_map

    jax.shard_map = _shard_map

if not hasattr(jax, "set_mesh"):

    @contextlib.contextmanager
    def _set_mesh(mesh):
        # jax.sharding.Mesh is itself a context manager (sets the global
        # resource env); NamedSharding-carrying jit does not strictly need
        # it, but shard_map/legacy pjit paths do.
        with mesh:
            yield mesh

    jax.set_mesh = _set_mesh
