"""GRASP-aware graph partitioning and the distributed GIN exchange.

The layout lifts the paper's Table I skew property to the partition tier.
After DBG reordering the hot vertices are a prefix of the id space and
cover the large majority of edge *sources*, so each device keeps a
three-region feature table:

    [0, hot)                        replicated hot prefix (every device)
    [hot, hot + cold_per_dev)       this device's own cold slice
    [hot + cold_per_dev, table_len) halo: published remote-cold rows,
                                    P contiguous per-owner blocks of c_pub

Edges live on the device that owns their destination (pull-based
aggregation), so only cold remote *sources* ever cross the network — the
minority path by construction. Per layer the exchange is two all_gathers:
own-hot slices -> full hot table, and each owner's published cold rows ->
the halo.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as PSpec

from repro.core import plan as plan_mod
from repro.nn import gnn as gnn_mod
from repro.nn import layers as L

# Per-device HBM each replica spends on the shared hot prefix. 64MB out of
# a v5e-class 16GB keeps replication cost <0.5% of device memory while
# covering the paper's Table I hot sets at 4B/elem.
HOT_REPLICA_BUDGET_BYTES = 64 << 20


@dataclasses.dataclass(frozen=True)
class GraspPartitionSpec:
    """Static shapes of a GRASP partition over `num_devices` devices.

    `num_nodes` is the padded node count (hot + num_devices*cold_per_dev);
    `n_own` nodes live on each device (its hot slice + its cold slice);
    `c_pub` bounds how many cold rows any owner publishes into the halo;
    `e_loc` bounds the per-device edge table; `table_len` is the local
    gather-table length hot + cold_per_dev + num_devices*c_pub.
    """
    num_devices: int
    num_nodes: int
    hot: int
    hot_per_dev: int
    cold_per_dev: int
    n_own: int
    c_pub: int
    e_loc: int
    table_len: int
    pub_frac: float
    edge_slack: float


def partition_spec_for(num_nodes: int, num_edges: int, num_devices: int,
                       hot: Optional[int] = None, pub_frac: float = 0.25,
                       edge_slack: float = 1.5,
                       hot_budget_bytes: Optional[int] = None,
                       elem_bytes: int = 4) -> GraspPartitionSpec:
    """Size the static buffers for a `num_devices`-way GRASP partition.

    `hot` may be given directly (tests / ablations) or derived from a real
    per-device memory budget: with `hot=None`, the replicated hot prefix is
    sized as `entries_for_budget(hot_budget_bytes, elem_bytes)` — the bytes
    each device can afford to spend on the replica, divided by the feature
    row size (`HOT_REPLICA_BUDGET_BYTES` when unspecified).

    `hot` is rounded down to a multiple of `num_devices`; the cold remainder
    is padded up so every device owns exactly `cold_per_dev` cold nodes.
    `pub_frac` scales the halo capacity (1.0 => any cold row may be
    published); `edge_slack` scales the per-device edge budget relative to
    a perfectly balanced split.
    """
    if num_devices < 1:
        raise ValueError("need at least one device")
    if hot is None:
        budget = (HOT_REPLICA_BUDGET_BYTES if hot_budget_bytes is None
                  else hot_budget_bytes)
        hot = plan_mod.entries_for_budget(budget, elem_bytes,
                                          max_entries=num_nodes)
    hot = int(max(0, min(hot, num_nodes)))
    hot -= hot % num_devices
    hot_per_dev = hot // num_devices
    cold = num_nodes - hot
    cold_per_dev = -(-cold // num_devices)  # ceil; 0 iff everything is hot
    padded = hot + num_devices * cold_per_dev
    if cold_per_dev > 0:
        c_pub = int(min(cold_per_dev, max(1, math.ceil(pub_frac * cold_per_dev))))
    else:
        c_pub = 0
    e_loc = max(1, math.ceil(edge_slack * num_edges / num_devices))
    return GraspPartitionSpec(
        num_devices=num_devices,
        num_nodes=padded,
        hot=hot,
        hot_per_dev=hot_per_dev,
        cold_per_dev=cold_per_dev,
        n_own=hot_per_dev + cold_per_dev,
        c_pub=c_pub,
        e_loc=e_loc,
        table_len=hot + cold_per_dev + num_devices * c_pub,
        pub_frac=float(pub_frac),
        edge_slack=float(edge_slack),
    )


def grasp_partition(g, spec: GraspPartitionSpec) -> Dict[str, np.ndarray]:
    """Build per-device edge tables addressing the three-region layout.

    Returns `esrc`/`edst`/`emask` of shape (P, e_loc) — local table indices
    and a validity mask, edges kept in CSR (dst-sorted) order so the
    distributed segment_sum reduces in the same order as the reference —
    plus `pub` (P, c_pub) of published *global* cold ids (0 = empty slot;
    id 0 is always hot or owned, never published), `dropped` (edges lost to
    halo/edge-budget overflow) and `total_edges`.
    """
    P = spec.num_devices
    hot, hpd, cpd = spec.hot, spec.hot_per_dev, spec.cold_per_dev
    src = np.asarray(g.indices, dtype=np.int64)
    dst = np.asarray(g.dst_ids(), dtype=np.int64)
    if g.num_nodes > spec.num_nodes:
        raise ValueError("spec was sized for a smaller graph")

    hpd_ = max(hpd, 1)  # avoid 0-division in unselected np.where branches
    cpd_ = max(cpd, 1)
    owner = np.where(dst < hot, dst // hpd_, (dst - hot) // cpd_)
    dst_local = np.where(dst < hot, dst - owner * hpd,
                         hpd + (dst - hot) - owner * cpd)
    src_owner = np.where(src < hot, -1, (src - hot) // cpd_)  # -1: hot (free)
    remote = src_owner != np.where(src < hot, -1, owner)
    remote &= src_owner >= 0

    # publish lists: per owner, the unique cold ids some other device needs
    pub = np.zeros((P, spec.c_pub), np.int32)
    halo_slot = np.full(spec.num_nodes, -1, np.int64)
    for q in range(P):
        ids = np.unique(src[remote & (src_owner == q)])
        n_q = min(ids.size, spec.c_pub)
        pub[q, :n_q] = ids[:n_q]
        halo_slot[ids[:n_q]] = hot + cpd + q * spec.c_pub + np.arange(n_q)

    own_local = hot + (src - hot) - src_owner * cpd  # valid when src is cold
    esrc_val = np.where(src < hot, src,
                        np.where(src_owner == owner, own_local,
                                 halo_slot[src]))
    addressable = esrc_val >= 0  # -1: remote-cold src beyond halo capacity

    esrc = np.zeros((P, spec.e_loc), np.int32)
    edst = np.zeros((P, spec.e_loc), np.int32)
    emask = np.zeros((P, spec.e_loc), bool)
    for p in range(P):
        sel = np.nonzero(addressable & (owner == p))[0]  # keeps CSR order
        k = min(sel.size, spec.e_loc)
        esrc[p, :k] = esrc_val[sel[:k]]
        edst[p, :k] = dst_local[sel[:k]]
        emask[p, :k] = True
    return {
        "esrc": esrc,
        "edst": edst,
        "emask": emask,
        "pub": pub,
        "dropped": int(g.num_edges - int(emask.sum())),
        "total_edges": int(g.num_edges),
    }


def make_grasp_gin_step(spec: GraspPartitionSpec, cfg, d_feat: int,
                        n_classes: int, mesh, opt_update,
                        overlap: bool = True) -> Tuple:
    """A shard_map GIN train step over a GRASP-partitioned graph.

    Batch dict (leading dim of sharded entries = device blocks):
      x_hot  (hot, d)           replicated hot features
      x_cold (P, cold_per_dev, d) own cold features
      esrc/edst/emask (P, e_loc)  local edge tables from `grasp_partition`
      pub    (P, c_pub)          published global cold ids
      labels (P, n_own)          labels in own-table order [hot | cold]

    Returns `(step, batch_specs)`; `step(params, opt_state, batch)` yields
    `(new_params, new_opt_state, {"loss": global_mean_nll})`, numerically
    matching the unpartitioned `gin_apply` loss (same per-destination edge
    order, f32 compute). `batch_specs` maps batch keys to spec-entry tuples
    for `sharding.ns`.

    `overlap=True` (the default) runs the software-pipelined exchange:
    gather tables are double-buffered across layers, layer l+1's hot and
    halo rows travel in ONE fused all_gather issued the moment h_{l+1}
    exists (a full layer of aggregation/MLP compute before the first
    consumer), and layer 0's hot table is `x_hot` itself — it is already
    replicated, so gathering own slices would only reassemble it. Every
    transformation is pure data movement, so loss and params are
    bit-identical to the `overlap=False` sequential step (collective
    count per step drops from 2L to L). `overlap=False` is the escape
    hatch that keeps the original gather-per-region schedule.
    """
    if cfg.kind != "gin":
        raise ValueError(f"grasp exchange step only supports gin, got {cfg.kind!r}")
    if int(mesh.size) != spec.num_devices:
        raise ValueError(f"mesh has {mesh.size} devices, spec wants "
                         f"{spec.num_devices}")
    axes = tuple(mesh.axis_names)
    hot, hpd, cpd = spec.hot, spec.hot_per_dev, spec.cold_per_dev
    P = spec.num_devices

    def fused_exchange(h, pub_local):
        """Double-buffer swap: one all_gather of [own hot slice | published
        cold rows] refreshes both the hot table and the halo for the NEXT
        layer. Issued right after h is produced and consumed a whole layer
        of compute later — the window XLA's latency-hiding scheduler can
        fill on real hardware."""
        d = h.shape[1]
        if spec.c_pub == 0:
            return jax.lax.all_gather(h[:hpd], axes, axis=0, tiled=True), None
        buf = jnp.concatenate([h[:hpd], jnp.take(h[hpd:], pub_local, axis=0)],
                              axis=0)
        g = jax.lax.all_gather(buf, axes, axis=0, tiled=True)
        g = g.reshape(P, hpd + spec.c_pub, d)
        return (g[:, :hpd].reshape(P * hpd, d),
                g[:, hpd:].reshape(P * spec.c_pub, d))

    def local_loss(params, x_hot, x_cold, esrc, edst, emask, pub, labels,
                   p_idx):
        # own table order is [own hot slice | own cold slice]
        h_hot_own = jax.lax.dynamic_slice_in_dim(x_hot, p_idx * hpd, hpd, 0)
        h = jnp.concatenate([h_hot_own, x_cold], axis=0)
        # this device's publish list: global ids -> positions in its own
        # cold slice (empty slots clip to row 0, which no edge addresses
        # through the halo)
        pub_local = jnp.clip(pub - (hot + p_idx * cpd), 0, max(cpd - 1, 0))
        layers = params["layers"]
        if overlap:
            # prologue: only the halo needs a collective before layer 0
            hot_full = x_hot
            halo = None
            if spec.c_pub > 0:
                halo = jax.lax.all_gather(
                    jnp.take(x_cold, pub_local, axis=0), axes, axis=0,
                    tiled=True)
        for li, lp in enumerate(layers):
            own_cold = h[hpd:]
            if overlap:
                parts = [hot_full, own_cold]
                if spec.c_pub > 0:
                    parts.append(halo)
            else:
                parts = [jax.lax.all_gather(h[:hpd], axes, axis=0, tiled=True),
                         own_cold]
                if spec.c_pub > 0:
                    published = jnp.take(own_cold, pub_local, axis=0)
                    parts.append(jax.lax.all_gather(published, axes, axis=0,
                                                    tiled=True))
            table = jnp.concatenate(parts, axis=0)
            msg = jnp.take(table, esrc, axis=0)
            msg = jnp.where(emask[:, None], msg, 0.0)
            agg = jax.ops.segment_sum(msg, edst, num_segments=spec.n_own)
            eps = lp["eps"] if lp["eps"] is not None else 0.0
            h = gnn_mod._mlp(lp["mlp"], (1.0 + eps) * h + agg)
            h = jax.nn.relu(L.layernorm(lp["ln"], h))
            if overlap and li + 1 < len(layers):
                hot_full, halo = fused_exchange(h, pub_local)
        logits = L.dense(params["out"], h, jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        return -ll.sum() / spec.num_nodes  # global mean after psum

    def sharded_step(params, opt_state, x_hot, x_cold, esrc, edst, emask,
                     pub, labels):
        # strip the leading device-block dim shard_map leaves on sharded args
        x_cold, esrc, edst, emask, pub, labels = (
            a[0] for a in (x_cold, esrc, edst, emask, pub, labels))
        p_idx = jax.lax.axis_index(axes)  # row-major linear device index
        lval, grads = jax.value_and_grad(local_loss)(
            params, x_hot, x_cold, esrc, edst, emask, pub, labels, p_idx)
        grads = jax.lax.psum(grads, axes)
        lval = jax.lax.psum(lval, axes)
        new_params, new_opt = opt_update(grads, opt_state, params)
        return new_params, new_opt, {"loss": lval}

    edge = PSpec(axes)
    sharded = shard_map(
        sharded_step, mesh,
        in_specs=(PSpec(), PSpec(), PSpec(), edge, edge, edge, edge, edge,
                  edge),
        out_specs=(PSpec(), PSpec(), PSpec()),
        check_rep=False,
    )

    def step(params, opt_state, batch):
        return sharded(params, opt_state, batch["x_hot"], batch["x_cold"],
                       batch["esrc"], batch["edst"], batch["emask"],
                       batch["pub"], batch["labels"])

    batch_specs = {
        "x_hot": (), "x_cold": (axes,), "esrc": (axes,), "edst": (axes,),
        "emask": (axes,), "pub": (axes,), "labels": (axes,),
    }
    return step, batch_specs
