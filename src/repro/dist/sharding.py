"""Sharding vocabulary for the launch layer.

Everything here speaks ``jax.sharding.PartitionSpec`` over the mesh axes the
launch layer uses: ``"pod"`` and ``"data"`` are batch/fsdp axes, ``"model"``
is tensor parallelism. Specs are written against the *largest* mesh (pod ×
data × model); ``ns``/``constrain`` silently drop axis names the concrete
mesh does not have, so the same spec tree works on debug meshes too.

``constrain`` additionally needs an active mesh to do anything — model code
(e.g. ``nn/transformer.py``) calls it unconditionally, including in
single-process unit tests where there is no mesh at all, so it degrades to
identity unless ``set_active_mesh`` has been called (dryrun/train do this
around lowering).
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ACTIVE_MESH: Optional[Mesh] = None


def set_active_mesh(mesh: Optional[Mesh]) -> None:
    """Make `constrain` emit with_sharding_constraint against `mesh`
    (None disables it again)."""
    global _ACTIVE_MESH
    _ACTIVE_MESH = mesh


def batch_axes(mesh: Mesh) -> tuple:
    """The data-parallel axes present on this mesh, outermost first."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _filter_entry(names, entry):
    """Drop mesh-axis names not present on this mesh from one spec entry."""
    if entry is None:
        return None
    if isinstance(entry, (tuple, list)):
        kept = tuple(a for a in entry if a in names)
        if not kept:
            return None
        return kept if len(kept) > 1 else kept[0]
    return entry if entry in names else None


def ns(mesh: Mesh, *axes) -> NamedSharding:
    """NamedSharding over `mesh` from spec entries, filtering absent axes.

    ``ns(mesh)`` is fully replicated; entries may be axis names, tuples of
    axis names, or None, exactly as in PartitionSpec.
    """
    names = set(mesh.axis_names)
    return NamedSharding(mesh, P(*(_filter_entry(names, a) for a in axes)))


def constrain(x, *axes):
    """with_sharding_constraint against the active mesh; identity if none.

    Besides filtering absent axis names, entries whose combined mesh-axis
    size does not divide the corresponding dim of `x` are dropped (the
    debug meshes are frequently larger than a smoke-test batch dim).
    """
    mesh = _ACTIVE_MESH
    if mesh is None:
        return x
    names = set(mesh.axis_names)
    entries = []
    for dim, entry in zip(x.shape, axes):
        entry = _filter_entry(names, entry)
        if entry is not None:
            ax = entry if isinstance(entry, tuple) else (entry,)
            size = 1
            for a in ax:
                size *= mesh.shape[a]
            if dim % size != 0:
                entry = None
        entries.append(entry)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*entries)))


# --------------------------------------------------------------------------
# LM (transformer) specs
# --------------------------------------------------------------------------

def lm_param_spec(cfg, fsdp: bool = True):
    """PartitionSpec tree matching the transformer param tree.

    Megatron-style: column-parallel in-projections, row-parallel
    out-projections over "model"; the non-TP dim is FSDP-sharded over the
    batch axes when `fsdp`. Layer params are stacked over a leading L dim
    (replicated). The tree may carry keys absent from a given config
    (e.g. "wg" on non-gated FFNs) — the launch layer broadcasts spec trees
    against value trees and ignores extras.
    """
    F = ("pod", "data") if fsdp else None
    col = P(None, F, "model")   # (L, d_in, d_out/TP)
    row = P(None, "model", F)   # (L, d_in/TP, d_out)
    layer = {
        "attn": {"wq": {"w": col}, "wk": {"w": col}, "wv": {"w": col},
                 "wo": {"w": row}},
        "ln1": P(),
        "ln2": P(),
        "ffn": {"wi": {"w": col}, "wg": {"w": col}, "wo": {"w": row}},
        "moe": {
            "router": {"w": P(None, F)},
            # raw stacked arrays (L, E, d_in, d_out)
            "wi": P(None, None, F, "model"),
            "wg": P(None, None, F, "model"),
            "wo": P(None, None, "model", F),
        },
    }
    return {
        "embed": P("model", F),
        "layers": layer,
        "ln_f": P(),
        "lm_head": {"w": P(F, "model")},
    }


def opt_state_spec(pspec, opt_name: str):
    """Optimizer-state spec tree from a param spec tree.

    Momentum-like slots shard exactly as the param; adafactor's factored
    second moment drops the corresponding reduced dim from the spec.
    """
    is_p = lambda x: isinstance(x, P)
    if opt_name == "sgd":
        return {"mu": pspec, "step": P()}
    if opt_name == "adamw":
        return {"m": pspec, "v": pspec, "step": P()}
    if opt_name == "adafactor":
        def second_moment(p):
            if len(p) < 2:
                # non-factored (vectors / scalars) -> {"v": ...} only; the
                # extra keys are harmless: spec trees are broadcast against
                # value trees key-by-key.
                return {"v": P(*p), "vr": P(), "vc": P()}
            return {
                "vr": P(*p[:-1]),                    # row stats: drop last dim
                "vc": P(*(tuple(p[:-2]) + (p[-1],))),  # col stats: drop 2nd-last
                "v": P(*p),
            }
        return {"v": jax.tree_util.tree_map(second_moment, pspec, is_leaf=is_p),
                "step": P()}
    raise ValueError(f"unknown optimizer {opt_name!r}")


def lm_batch_spec(mesh: Mesh):
    """Token batches shard over the data axes on dim 0."""
    b = batch_axes(mesh)
    return {"tokens": P(b, None), "labels": P(b, None)}


# --------------------------------------------------------------------------
# GNN / recsys batch & param specs
# --------------------------------------------------------------------------

def gnn_batch_spec(mesh: Mesh, kind: str):
    """Spec-entry tuples (splatted into `ns`) for sharded GNN batch keys.

    Edge arrays shard over every mesh axis; node arrays stay replicated
    (segment_sum pulls messages back to replicated node tables), so they
    are simply omitted — the launch layer replicates unlisted keys. `kind`
    (full_graph / molecule / minibatch) currently shares one layout.
    """
    A = tuple(mesh.axis_names)
    return {"src": (A,), "dst": (A,), "emask": (A,)}


def recsys_param_spec(cfg, grasp: bool = False):
    """MIND param specs: the item table is the only big tensor.

    With `grasp`, the hot rows are replicated (they serve most lookups —
    the same skew the cache policy exploits) and only the cold table is
    sharded.
    """
    A = ("pod", "data", "model")
    spec = {"s_mat": P(), "mlp": P()}
    if grasp:
        spec["items_hot"] = P()
        spec["items_cold"] = P(A, None)
    else:
        spec["items"] = P(A, None)
    return spec


def recsys_batch_spec(mesh: Mesh, kind: str):
    b = batch_axes(mesh)
    A = tuple(mesh.axis_names)
    if kind == "train":
        return {"hist": P(b, None), "hist_mask": P(b, None),
                "target": P(b), "negatives": P()}
    if kind == "serve":
        return {"hist": P(b, None), "hist_mask": P(b, None),
                "candidates": P(b, None)}
    if kind == "retrieval":
        return {"hist": P(), "hist_mask": P(), "candidates": P(A)}
    raise ValueError(f"unknown recsys shape kind {kind!r}")
