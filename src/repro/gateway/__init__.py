"""repro.gateway — async RPC serving front-end for the repro.serve tier.

``pump`` runs one background thread per engine that continuously drains
the continuous batcher; ``server`` is the stdlib ThreadingHTTPServer
JSON-RPC front-end (``/v1/generate``, ``/v1/score``, ``/healthz``,
``/metrics``); ``client`` is the urllib client with typed errors and
bounded-backoff retries on 503; ``errors`` is the shared taxonomy. See
README.md in this directory for the architecture and drain protocol.
"""
from repro.gateway.client import GatewayClient
from repro.gateway.errors import (
    Failed,
    GatewayError,
    Rejected,
    Shed,
    Timeout,
    error_for_status,
)
from repro.gateway.pump import EnginePump
from repro.gateway.server import GatewayServer

__all__ = [
    "EnginePump",
    "GatewayServer",
    "GatewayClient",
    "GatewayError",
    "Rejected",
    "Shed",
    "Timeout",
    "Failed",
    "error_for_status",
]
