"""repro.gateway — async RPC serving front-end for the repro.serve tier.

``pump`` runs one background thread per engine that continuously drains
the continuous batcher; ``supervisor`` is the watchdog that detects
dead/wedged pump threads and restarts them with backoff; ``breaker`` is
the per-route circuit breaker that sheds a persistently failing engine
fast; ``server`` is the stdlib ThreadingHTTPServer JSON-RPC front-end
(``/v1/generate``, ``/v1/score``, ``/healthz``, ``/metrics``) with
idempotency-key dedupe and warm-restart cache snapshots; ``client`` is
the urllib client with typed errors and bounded-backoff retries on 503;
``errors`` is the shared taxonomy. See README.md in this directory for
the architecture, the drain protocol, and the failure-modes table.
"""
from repro.gateway.breaker import CircuitBreaker
from repro.gateway.client import GatewayClient
from repro.gateway.errors import (
    Failed,
    GatewayError,
    Rejected,
    Shed,
    Timeout,
    Unavailable,
    error_for_status,
)
from repro.gateway.pump import EnginePump
from repro.gateway.server import GatewayServer, IdempotencyCache
from repro.gateway.supervisor import PumpSupervisor

__all__ = [
    "EnginePump",
    "PumpSupervisor",
    "CircuitBreaker",
    "GatewayServer",
    "IdempotencyCache",
    "GatewayClient",
    "GatewayError",
    "Rejected",
    "Shed",
    "Unavailable",
    "Timeout",
    "Failed",
    "error_for_status",
]
