"""Per-route circuit breaker: shed a persistently failing engine fast.

A single engine fault is absorbed by the pump (batch fails, loop
continues) and a dying pump thread is restarted by the supervisor — but
when the engine fails *persistently* (bad weights, poisoned jit cache,
chaos schedule with a high fault rate), every request still pays a full
queue + forward round-trip just to collect a 500, and the supervisor
burns restart budget on an engine that cannot serve. The breaker cuts
that path at the route level with the classic three states:

  closed     normal serving; ``failure_threshold`` *consecutive* route
             failures (engine 500s) trip it open. Any success resets the
             streak — intermittent faults never open the breaker.
  open       requests are shed immediately with ``Unavailable`` (503 +
             Retry-After = remaining cooldown) — no queue entry, no
             forward. After ``cooldown_s`` the next request is let
             through as a probe (-> half-open).
  half_open  up to ``half_open_probes`` concurrent probes run the real
             path; one success closes the breaker (streak reset), one
             failure reopens it for another full cooldown.

Only *engine* failures count: ``Failed`` (forward raised) and unexpected
handler errors. Backpressure outcomes — ``Rejected``/``Shed``/``Timeout``
— are the scheduler doing its job and must not open the breaker.

The clock is injectable for deterministic tests. Thread-safe: handler
threads race on ``before``/``record``.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict

from repro.gateway.errors import Unavailable


class CircuitBreaker:
    def __init__(
        self,
        failure_threshold: int = 5,
        cooldown_s: float = 1.0,
        half_open_probes: int = 1,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        self.failure_threshold = int(failure_threshold)
        self.cooldown_s = float(cooldown_s)
        self.half_open_probes = int(half_open_probes)
        self.clock = clock
        self.state = "closed"
        self.opened = 0               # total open transitions
        self.shed = 0                 # requests refused while open
        self._streak = 0              # consecutive failures while closed
        self._opened_at = 0.0
        self._probes = 0              # in-flight half-open probes
        self._lock = threading.Lock()

    def before(self) -> None:
        """Gate one request; raises ``Unavailable`` when open (and not yet
        due for a probe). Callers MUST follow with ``record_success`` or
        ``record_failure`` so half-open probe slots are released."""
        with self._lock:
            if self.state == "open":
                remaining = self._opened_at + self.cooldown_s - self.clock()
                if remaining > 0:
                    self.shed += 1
                    raise Unavailable(
                        f"circuit open ({self._streak} consecutive failures); "
                        f"retry in {remaining:.3f}s",
                        retry_after_s=max(remaining, 1e-3))
                self.state = "half_open"
                self._probes = 0
            if self.state == "half_open":
                if self._probes >= self.half_open_probes:
                    remaining = self._opened_at + self.cooldown_s - self.clock()
                    self.shed += 1
                    raise Unavailable(
                        "circuit half-open, probe already in flight",
                        retry_after_s=max(remaining, self.cooldown_s / 2))
                self._probes += 1

    def record_success(self) -> None:
        with self._lock:
            if self.state == "half_open":
                self._probes = max(0, self._probes - 1)
            self.state = "closed"
            self._streak = 0

    def record_neutral(self) -> None:
        """Outcome that says nothing about engine health (reject/shed/
        timeout): release a half-open probe slot without closing or
        reopening — the next request probes again."""
        with self._lock:
            if self.state == "half_open":
                self._probes = max(0, self._probes - 1)

    def record_failure(self) -> None:
        with self._lock:
            if self.state == "half_open":
                # the probe failed: the engine is still down — reopen
                self._probes = max(0, self._probes - 1)
                self._open()
                return
            self._streak += 1
            if self.state == "closed" and self._streak >= self.failure_threshold:
                self._open()

    def _open(self) -> None:   # caller holds the lock
        self.state = "open"
        self.opened += 1
        self._opened_at = self.clock()

    def stats(self) -> Dict:
        with self._lock:
            return {"state": self.state, "opened": self.opened,
                    "shed": self.shed, "streak": self._streak}
