"""urllib-based gateway client: typed errors, per-request timeouts, and
bounded exponential-backoff retries on 503.

503 is the gateway's backpressure signal (admission-control reject,
deadline shed, or an open circuit breaker — all transient by
construction: load moves, deadlines reset on re-entry, breakers cool
down), so the client absorbs up to ``retries`` of them with
``backoff_s * factor**attempt`` sleeps capped at ``backoff_cap_s``, then
raises the typed error from the *last* response (``Rejected``, ``Shed``
or ``Unavailable`` from ``gateway.errors``). 504 and socket-level
timeouts raise ``Timeout`` immediately; 500 raises ``Failed`` immediately
— retrying a crashed batch only re-crashes it. A malformed ``Retry-After``
header is ignored (computed backoff applies), never a crash.

Every POST carries a client-generated ``Idempotency-Key`` header, held
constant across that logical request's retries: a retry after a
connection reset may re-send a request the server already executed, and
the key lets the server-side dedupe LRU replay the recorded outcome
instead of double-executing ``/v1/generate``.

``stats`` counts attempts/retries/recoveries (thread-safe), which is how
the smoke benchmarks assert that transient 503s actually recover. The
single-attempt transport lives in the ``_open`` hook so chaos tooling
(``repro.chaos.ChaosClient``) can inject connection resets underneath
the retry loop.
"""
from __future__ import annotations

import http.client
import json
import socket
import threading
import time
import urllib.error
import urllib.request
import uuid
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.gateway.errors import GatewayError, Timeout, error_for_status


def _parse_retry_after(value: Optional[str]) -> Optional[float]:
    """Defensive Retry-After parse: seconds as float, else None (callers
    fall back to the computed backoff). The header reaches us from the
    network — a malformed value must never crash the retry loop."""
    if not value:
        return None
    try:
        parsed = float(value)
    except (TypeError, ValueError):
        return None
    return parsed if parsed >= 0.0 and np.isfinite(parsed) else None


class GatewayClient:
    def __init__(
        self,
        base_url: str,
        timeout_s: float = 30.0,
        retries: int = 5,
        backoff_s: float = 0.05,
        backoff_factor: float = 2.0,
        backoff_cap_s: float = 2.0,
    ) -> None:
        self.base_url = base_url.rstrip("/")
        self.timeout_s = float(timeout_s)
        self.retries = int(retries)
        self.backoff_s = float(backoff_s)
        self.backoff_factor = float(backoff_factor)
        self.backoff_cap_s = float(backoff_cap_s)
        self.stats = {"attempts": 0, "retries_503": 0, "retries_conn": 0,
                      "recovered": 0}
        self._lock = threading.Lock()

    # -- wire ------------------------------------------------------------
    def _count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.stats[name] += n

    def _backoff(self, attempt: int) -> float:
        return min(self.backoff_cap_s,
                   self.backoff_s * self.backoff_factor ** attempt)

    def _open(self, req: urllib.request.Request, timeout: float) -> Dict:
        """One transport attempt: send, read, parse. Overridable hook —
        ``repro.chaos.ChaosClient`` injects connection resets here."""
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return json.loads(resp.read())

    def _request(self, path: str, obj: Optional[Dict] = None,
                 timeout_s: Optional[float] = None,
                 retry: bool = True, raise_for_status: bool = True) -> Dict:
        url = self.base_url + path
        data = None if obj is None else json.dumps(obj).encode()
        timeout = self.timeout_s if timeout_s is None else float(timeout_s)
        max_attempts = (self.retries if retry else 0) + 1
        last_err: Optional[GatewayError] = None
        headers = {"Content-Type": "application/json"}
        if data is not None:
            # one key per *logical* request, constant across its retries:
            # the server's dedupe LRU replays instead of re-executing
            headers["Idempotency-Key"] = uuid.uuid4().hex
        for attempt in range(max_attempts):
            self._count("attempts")
            req = urllib.request.Request(
                url, data=data, headers=headers,
                method="POST" if data is not None else "GET")
            try:
                out = self._open(req, timeout)
                if attempt > 0:
                    self._count("recovered")
                return out
            except urllib.error.HTTPError as e:
                try:
                    body = json.loads(e.read())
                except (json.JSONDecodeError, ValueError):
                    body = {}
                if not raise_for_status:
                    return body      # status report, not an error (healthz)
                last_err = error_for_status(
                    body.get("error", "error"),
                    body.get("detail", f"HTTP {e.code} from {path}"),
                    retry_after_s=_parse_retry_after(
                        e.headers.get("Retry-After")))
                if e.code != 503 or attempt + 1 >= max_attempts:
                    raise last_err from None
                self._count("retries_503")
                wait = last_err.retry_after_s or 0.0
                time.sleep(max(wait, self._backoff(attempt)))
            except (socket.timeout, TimeoutError) as e:
                raise Timeout(f"{path}: no response within {timeout}s") from e
            except (ConnectionError, http.client.RemoteDisconnected,
                    urllib.error.URLError) as e:
                if isinstance(e, urllib.error.URLError):
                    if isinstance(e.reason, (socket.timeout, TimeoutError)):
                        raise Timeout(
                            f"{path}: no response within {timeout}s") from e
                    if not isinstance(e.reason, (ConnectionError, OSError)):
                        raise
                # transient transport fault (reset/refused mid-burst):
                # retryable with the same backoff as a 503
                last_err = GatewayError(f"{path}: connection error: {e}")
                if attempt + 1 >= max_attempts:
                    raise last_err from e
                self._count("retries_conn")
                time.sleep(self._backoff(attempt))
        raise last_err  # unreachable: loop either returned or raised

    def _socket_timeout(self, timeout_s: Optional[float]) -> Optional[float]:
        """Socket timeout = server wait budget + margin, so the server's own
        504 (typed, with the request id) wins the race against the socket."""
        return None if timeout_s is None else float(timeout_s) + 2.0

    # -- API -------------------------------------------------------------
    def score(self, hist: Sequence[int], candidates: Sequence[int],
              hist_mask: Optional[Sequence[bool]] = None,
              deadline_ms: Optional[float] = None,
              timeout_s: Optional[float] = None) -> np.ndarray:
        """Score ``candidates`` against a user ``hist``; returns (C,)."""
        obj: Dict = {"hist": np.asarray(hist).tolist(),
                     "candidates": np.asarray(candidates).tolist()}
        if hist_mask is not None:
            obj["hist_mask"] = np.asarray(hist_mask, bool).tolist()
        if deadline_ms is not None:
            obj["deadline_ms"] = float(deadline_ms)
        if timeout_s is not None:
            obj["timeout_s"] = float(timeout_s)
        out = self._request("/v1/score", obj,
                            timeout_s=self._socket_timeout(timeout_s))
        return np.asarray(out["scores"], np.float32)

    def generate(self, tokens: Sequence[int],
                 deadline_ms: Optional[float] = None,
                 timeout_s: Optional[float] = None) -> List[int]:
        """Greedy continuation of a prompt; returns the decoded ids."""
        obj: Dict = {"tokens": np.asarray(tokens).tolist()}
        if deadline_ms is not None:
            obj["deadline_ms"] = float(deadline_ms)
        if timeout_s is not None:
            obj["timeout_s"] = float(timeout_s)
        out = self._request("/v1/generate", obj,
                            timeout_s=self._socket_timeout(timeout_s))
        return list(out["tokens"])

    def health(self) -> Dict:
        """Readiness probe. Unlike the serving routes, a non-2xx here is a
        *report*, not an error: a degraded gateway answers 503 with the
        same JSON body, which callers want to inspect, not catch."""
        return self._request("/healthz", retry=False, raise_for_status=False)

    def metrics(self) -> Dict:
        return self._request("/metrics", retry=False)
