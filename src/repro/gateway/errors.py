"""Gateway error taxonomy, shared by pump, server, and client.

Each terminal ``Request`` status that is not ``done`` maps to exactly one
exception type, and each type maps to one HTTP status on the wire, so the
client can reconstruct server-side outcomes without parsing prose:

  ========== ===================================== ===========
  exception  meaning                               HTTP status
  ========== ===================================== ===========
  Rejected   admission control: queue full, or the 503
             server is draining — backpressure,
             retryable after backoff
  Shed       admitted but its deadline expired in  503
             queue — retryable (a retry re-enters
             with a fresh deadline)
  Unavailable the route's circuit breaker is open  503
             (persistent engine faults) or its
             pump is crash-looping — retryable
             after the breaker's cooldown
  Timeout    the caller's wait/deadline elapsed    504
             before the request resolved
  Failed     the engine forward raised — not       500
             retryable by default
  ========== ===================================== ===========

All 503 flavours are *transient*: the client's bounded exponential
backoff retries them. ``retry_after_s`` carries the server's Retry-After
hint when one was given (for ``Unavailable`` it is the breaker's
remaining cooldown — retrying sooner is guaranteed to shed again).
"""
from __future__ import annotations

from typing import Optional


class GatewayError(Exception):
    """Base class for every gateway-side request failure."""

    http_status = 500
    kind = "error"

    def __init__(self, message: str = "",
                 retry_after_s: Optional[float] = None) -> None:
        super().__init__(message or self.kind)
        self.retry_after_s = retry_after_s


class Rejected(GatewayError):
    """Admission control turned the request away (queue full / draining)."""

    http_status = 503
    kind = "rejected"


class Shed(GatewayError):
    """Admitted, but shed in queue when its deadline expired."""

    http_status = 503
    kind = "shed"


class Unavailable(GatewayError):
    """The route is shedding fast: circuit breaker open after persistent
    engine faults, or the pump is crash-looping beyond its restart budget."""

    http_status = 503
    kind = "unavailable"


class Timeout(GatewayError):
    """The caller's wait budget elapsed before the request resolved."""

    http_status = 504
    kind = "timeout"


class Failed(GatewayError):
    """The engine forward raised while serving this request's batch."""

    http_status = 500
    kind = "failed"


_BY_KIND = {c.kind: c for c in (Rejected, Shed, Unavailable, Timeout, Failed)}


def error_for_status(status: str, message: str = "",
                     retry_after_s: Optional[float] = None) -> GatewayError:
    """Map a terminal ``Request.status`` / wire ``error`` kind to its
    exception (unknown kinds degrade to the ``GatewayError`` base)."""
    cls = _BY_KIND.get(status, GatewayError)
    return cls(message or status, retry_after_s=retry_after_s)
