"""Engine pump: the background thread that turns a serve engine into an
async component.

``repro.serve`` engines are passive — someone must call ``step()`` to
drain the batcher. In the simulated drivers that someone is the benchmark
loop on a virtual clock; behind a real RPC front-end it is this pump: one
daemon thread per engine that continuously claims the next batch, runs
the forward, and completes it, while HTTP handler threads block only on
their *own* request's completion event (``Request.done`` — no polling, no
global barrier).

Liveness invariants (what makes the gateway hang-free):

- every submitted request reaches a terminal status: rejects resolve
  synchronously in ``submit``, sheds resolve inside ``next_batch``, served
  requests resolve in ``complete``, and a forward that *raises* resolves
  its whole batch via ``ContinuousBatcher.fail`` — the exception is
  attached to the requests instead of killing the pump;
- ``result()`` converts terminal statuses to the typed taxonomy in
  ``gateway.errors`` and enforces the caller's wait budget (``Timeout``);
- graceful drain: ``drain()`` closes admissions (new submits raise
  ``Rejected``), lets queued work finish (expired entries shed as usual),
  and ``close()`` then stops and joins the thread. Shutdown can strand
  nothing: whatever is still queued when the drain budget runs out is
  failed out explicitly.

What the pump can NOT absorb on its own — the failure mode
``gateway.supervisor.PumpSupervisor`` exists for — is the loop itself
dying: a ``next_batch`` that raises (scheduler bug, injected chaos)
escapes the forward try/except and terminates the thread. The pump
records the cause in ``crash``/``crashes`` and exits cleanly instead of
dumping a traceback, and every loop iteration stamps ``last_beat`` so a
watchdog can tell *dead* (thread gone) from *wedged* (heartbeat stale
while a batch is in flight). Restart is generation-based: ``restart()``
bumps ``generation`` and spawns a fresh thread; a wedged predecessor that
eventually unwedges notices the stale generation and exits without
touching the batcher again (terminal statuses are idempotent in
``complete``/``fail``, so a late completion of a failed-out batch is a
no-op).
"""
from __future__ import annotations

import threading
import time
from typing import Any, List, Optional

from repro.gateway.errors import GatewayError, Rejected, Timeout, error_for_status
from repro.serve.scheduler import Request

# idle pumps park on this wait; submits wake them immediately via the event
_IDLE_WAIT_S = 0.005


class EnginePump:
    """Background continuous-batching loop around one serve engine.

    ``engine`` needs the ``_EngineBase`` surface: ``.batcher`` and
    ``.forward(payloads)``. The pump is started explicitly (``start()`` or
    context manager) and runs until ``close()``.
    """

    def __init__(self, engine, name: str = "engine") -> None:
        self.engine = engine
        self.name = name
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._closed = False          # admissions closed (draining/stopped)
        self._busy = False            # a claimed batch is in flight
        self._busy_since: Optional[float] = None
        self._inflight: List[Request] = []
        self._gen = 0                 # bumped by every (re)spawn
        self._gen_lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        # liveness/introspection, read by the supervisor and /healthz
        self.last_beat: float = 0.0   # monotonic stamp of the last loop tick
        self.crash: Optional[BaseException] = None   # last loop-killing error
        self.crashes: int = 0         # pump-thread deaths (next_batch raised)

    # -- lifecycle -------------------------------------------------------
    def _spawn(self) -> None:
        with self._gen_lock:
            self._gen += 1
            gen = self._gen
            self.last_beat = time.monotonic()
            self._thread = threading.Thread(
                target=self._run, args=(gen,),
                name=f"pump-{self.name}-g{gen}", daemon=True)
            self._thread.start()

    def start(self) -> "EnginePump":
        if self._thread is None and not self._stop.is_set():
            self._spawn()             # idempotent: first start only
        return self

    def restart(self) -> bool:
        """Abandon the current pump thread and spawn a fresh one (the
        supervisor's recovery action). The old thread — dead, or wedged in
        a forward that may never return — sees the stale generation on its
        next loop check and exits without re-entering the batcher. Returns
        False when the pump was never started or is already closed."""
        if self._thread is None or self._stop.is_set():
            return False
        self._busy = False
        self._busy_since = None
        self._spawn()
        return True

    @property
    def started(self) -> bool:
        return self._thread is not None

    @property
    def generation(self) -> int:
        return self._gen

    def __enter__(self) -> "EnginePump":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    @property
    def draining(self) -> bool:
        return self._closed

    @property
    def busy_for_s(self) -> float:
        """Seconds the current batch has been in flight (0 when idle)."""
        since = self._busy_since
        return 0.0 if since is None else time.monotonic() - since

    def _run(self, gen: int) -> None:
        batcher = self.engine.batcher
        try:
            while not self._stop.is_set() and gen == self._gen:
                self.last_beat = time.monotonic()
                # busy is raised BEFORE the claim so drain() can never observe
                # "queue empty + not busy" between next_batch and complete
                self._busy = True
                self._busy_since = time.monotonic()
                batch = batcher.next_batch()
                if not batch:
                    self._busy = False
                    self._busy_since = None
                    self._wake.wait(_IDLE_WAIT_S)
                    self._wake.clear()
                    continue
                self._inflight = batch
                try:
                    results = self.engine.forward([r.payload for r in batch])
                    batcher.complete(batch, list(results))
                except Exception as exc:   # noqa: BLE001 — resolve, don't die
                    batcher.fail(batch, exc)
                finally:
                    if gen == self._gen:   # a superseded thread must not
                        self._inflight = []          # clobber its successor's
                        self._busy = False           # liveness state
                        self._busy_since = None
        except Exception as exc:  # noqa: BLE001 — next_batch raised: the loop
            # cannot continue. Record the cause and exit; the supervisor (if
            # any) detects the death and restarts a fresh generation.
            self.crash = exc
            self.crashes += 1
            if gen == self._gen:
                self._busy = False
                self._busy_since = None

    # -- request path ----------------------------------------------------
    def submit(self, payload: Any,
               deadline_s: Optional[float] = None) -> Request:
        """Enqueue one request; raises ``Rejected`` when admissions are
        closed (draining) or the queue is full."""
        if self._closed:
            raise Rejected(f"{self.name}: draining, admissions closed")
        req = self.engine.batcher.submit(payload, deadline_s)
        if req.status == "rejected":
            raise Rejected(f"{self.name}: queue full "
                           f"({self.engine.batcher.config.max_queue})")
        self._wake.set()
        return req

    def result(self, req: Request, timeout: Optional[float] = None) -> Any:
        """Block on ``req``'s completion event; return its result or raise
        the typed error for its terminal status."""
        if not req.wait(timeout):
            raise Timeout(f"{self.name}: request {req.rid} unresolved "
                          f"after {timeout}s")
        if req.status == "done":
            return req.result
        raise error_for_status(req.status, f"{self.name}: request {req.rid} "
                                           f"{req.status} ({req.error})")

    def call(self, payload: Any, deadline_s: Optional[float] = None,
             timeout: Optional[float] = None) -> Any:
        """submit + result — the synchronous convenience used by handlers."""
        return self.result(self.submit(payload, deadline_s), timeout)

    # -- drain / shutdown ------------------------------------------------
    def drain(self, timeout: Optional[float] = 30.0) -> bool:
        """Stop admissions and wait for in-flight work to finish.

        Returns True when the queue emptied and the last batch completed
        within ``timeout``; on False the caller may still ``close()`` —
        leftovers are failed out rather than stranded. A dead pump cannot
        drain its queue: bail out immediately instead of burning the whole
        budget polling a thread that will never claim again.
        """
        self._closed = True
        deadline = None if timeout is None else time.monotonic() + timeout
        while self.engine.batcher.depth > 0 or self._busy:
            if not self.running:
                return self.engine.batcher.depth == 0 and not self._busy
            if deadline is not None and time.monotonic() > deadline:
                return False
            self._wake.set()
            time.sleep(_IDLE_WAIT_S / 5)
        return True

    def close(self, timeout: Optional[float] = 30.0) -> None:
        """Graceful shutdown: drain, stop the loop, join the thread, and
        fail out anything the drain budget left behind."""
        self.drain(timeout)
        self._stop.set()
        self._wake.set()
        if self._thread is not None:   # never-started pumps have no thread
            self._thread.join(timeout)
        # a drain timeout (or a never-started/dead pump) can leave queued
        # requests behind — resolve them so no caller hangs. Claiming via
        # next_batch keeps the shed-vs-failed distinction for expired
        # entries, but the claim path itself may be what is broken (the
        # very next_batch crash that killed the pump): fall back to
        # failing the raw queue out directly.
        exc = GatewayError("pump closed before serving")
        try:
            leftovers = self.engine.batcher.next_batch()
            while leftovers:
                self.engine.batcher.fail(leftovers, exc)
                leftovers = self.engine.batcher.next_batch()
        except Exception:  # noqa: BLE001 — close() must never raise
            pass
        self.engine.batcher.fail_all(exc)
