"""JSON-RPC serving front-end over ``http.server.ThreadingHTTPServer``.

Stdlib-only by design (the container has no web framework): one daemon
thread per connection, each handler thread submits into the shared
``EnginePump`` and blocks on its own request's completion event. The
pump's scheduler is the single point of truth for admission control —
the server merely translates its outcomes onto the wire:

  ``POST /v1/generate``  LM prefill+decode   {"tokens": [...]} -> {"tokens": [[...], ...]}
  ``POST /v1/score``     recsys scoring      {"hist": [...], "candidates": [...]} -> {"scores": [...]}
  ``GET  /healthz``      liveness + drain state
  ``GET  /metrics``      per-engine ``ServeMetrics.snapshot()``

Error mapping (see ``gateway.errors``): admission-control rejects and
deadline sheds answer **503** with a ``Retry-After`` hint — the
backpressure signal the client's bounded exponential backoff keys on;
caller-budget expiry answers 504; an engine fault answers 500. Request
bodies may carry ``deadline_ms`` (queue deadline, defaults to the
scheduler's) and ``timeout_s`` (caller wait budget).

``stop()`` is the graceful-drain protocol: mark draining (new requests are
rejected with 503), ``close()`` every pump (stop admissions, finish
in-flight batches, join the pump thread), then shut the listener down.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

import numpy as np

from repro.gateway.errors import GatewayError, Rejected
from repro.gateway.pump import EnginePump


class _BadRequest(Exception):
    """Malformed request body — answered with 400, never enters the pump."""


class _HTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    # socketserver's default listen backlog is 5 — an open-loop arrival
    # burst would see connection resets before admission control ever runs
    request_queue_size = 1024
    gateway: "GatewayServer"


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server: _HTTPServer

    def log_message(self, fmt, *args):  # quiet: metrics cover observability
        pass

    def _send_json(self, code: int, obj: Dict,
                   headers: Optional[Dict[str, str]] = None) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:
        gw = self.server.gateway
        if self.path == "/healthz":
            self._send_json(200, gw.health())
        elif self.path == "/metrics":
            self._send_json(200, gw.metrics())
        else:
            self._send_json(404, {"error": "not_found", "detail": self.path})

    def do_POST(self) -> None:
        gw = self.server.gateway
        route = gw.routes.get(self.path)
        if route is None:
            self._send_json(404, {"error": "not_found", "detail": self.path})
            return
        try:
            length = int(self.headers.get("Content-Length") or 0)
            obj = json.loads(self.rfile.read(length) or b"{}")
            if not isinstance(obj, dict):
                raise _BadRequest("body must be a JSON object")
            self._send_json(200, route(obj))
        except _BadRequest as e:
            self._send_json(400, {"error": "bad_request", "detail": str(e)})
        except (json.JSONDecodeError, ValueError, TypeError) as e:
            self._send_json(400, {"error": "bad_request", "detail": str(e)})
        except GatewayError as e:
            headers = ({"Retry-After": f"{gw.retry_after_s:.3f}"}
                       if e.http_status == 503 else {})
            self._send_json(e.http_status,
                            {"error": e.kind, "detail": str(e)}, headers)
        except Exception as e:  # noqa: BLE001 — surface bugs as 500s
            self._send_json(500, {"error": "error", "detail": repr(e)})


class GatewayServer:
    """HTTP front-end over named engine pumps.

    ``pumps`` maps route names to pumps: ``"generate"`` mounts
    ``/v1/generate`` (an ``LMServeEngine``), ``"score"`` mounts
    ``/v1/score`` (a ``RecsysServeEngine``). ``port=0`` binds an ephemeral
    port — read it back from ``.address``/``.url`` (loopback tests).
    """

    def __init__(
        self,
        pumps: Dict[str, EnginePump],
        host: str = "127.0.0.1",
        port: int = 0,
        request_timeout_s: float = 30.0,
        retry_after_s: float = 0.05,
    ) -> None:
        self.pumps = dict(pumps)
        self.request_timeout_s = float(request_timeout_s)
        self.retry_after_s = float(retry_after_s)
        self.routes = {}
        if "generate" in self.pumps:
            self.routes["/v1/generate"] = self._generate
        if "score" in self.pumps:
            self.routes["/v1/score"] = self._score
        self._draining = False
        self._httpd = _HTTPServer((host, port), _Handler)
        self._httpd.gateway = self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="gateway-http", daemon=True)

    # -- lifecycle -------------------------------------------------------
    @property
    def address(self) -> Tuple[str, int]:
        return self._httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def start(self) -> "GatewayServer":
        for pump in self.pumps.values():
            pump.start()
        self._thread.start()
        return self

    def stop(self, drain_timeout_s: float = 30.0) -> None:
        """Graceful drain: reject new work, finish in-flight, shut down."""
        self._draining = True
        for pump in self.pumps.values():
            pump.close(drain_timeout_s)
        if self._thread.ident is not None:   # shutdown() blocks forever if
            self._httpd.shutdown()           # serve_forever never started
            self._thread.join(5.0)
        self._httpd.server_close()

    def __enter__(self) -> "GatewayServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- introspection ---------------------------------------------------
    def health(self) -> Dict:
        return {
            "status": "draining" if self._draining else "ok",
            "engines": {
                name: {"depth": pump.engine.batcher.depth,
                       "draining": pump.draining,
                       "running": pump.running}
                for name, pump in self.pumps.items()
            },
        }

    def metrics(self) -> Dict:
        return {name: pump.engine.metrics.snapshot()
                for name, pump in self.pumps.items()}

    # -- routes ----------------------------------------------------------
    def _budgets(self, obj: Dict) -> Tuple[Optional[float], float]:
        deadline_ms = obj.get("deadline_ms")
        deadline_s = None if deadline_ms is None else float(deadline_ms) / 1e3
        return deadline_s, float(obj.get("timeout_s", self.request_timeout_s))

    def _call(self, pump: EnginePump, payload: Dict, obj: Dict):
        if self._draining:
            raise Rejected("gateway draining")
        deadline_s, timeout_s = self._budgets(obj)
        return pump.call(payload, deadline_s=deadline_s, timeout=timeout_s)

    def _score(self, obj: Dict) -> Dict:
        pump = self.pumps["score"]
        cfg = pump.engine.cfg
        hist = np.asarray(obj.get("hist", []), dtype=np.int64).ravel()
        cand = np.asarray(obj.get("candidates", []), dtype=np.int64).ravel()
        if hist.size == 0 or cand.size == 0:
            raise _BadRequest("'hist' and 'candidates' are required")
        for name, ids in (("hist", hist), ("candidates", cand)):
            if ids.min() < 0 or ids.max() >= cfg.n_items:
                raise _BadRequest(
                    f"'{name}' ids must be in [0, {cfg.n_items})")
        h = hist[-cfg.hist_len:]
        full = np.zeros(cfg.hist_len, np.int32)
        mask = np.zeros(cfg.hist_len, bool)
        full[: h.size] = h
        mask[: h.size] = True
        if "hist_mask" in obj:
            m = np.asarray(obj["hist_mask"], dtype=bool).ravel()[-cfg.hist_len:]
            mask[: m.size] &= m[: m.size]
        payload = {"hist": full, "hist_mask": mask,
                   "candidates": cand.astype(np.int32)}
        scores = self._call(pump, payload, obj)
        return {"scores": np.asarray(scores, np.float64).tolist()}

    def _generate(self, obj: Dict) -> Dict:
        pump = self.pumps["generate"]
        toks = obj.get("tokens")
        if not toks or not isinstance(toks, list):
            raise _BadRequest("'tokens' must be a non-empty list of ids")
        payload = {"tokens": np.asarray(toks, np.int32)}
        out = self._call(pump, payload, obj)
        return {"tokens": np.asarray(out, np.int64).tolist()}
