"""JSON-RPC serving front-end over ``http.server.ThreadingHTTPServer``.

Stdlib-only by design (the container has no web framework): one daemon
thread per connection, each handler thread submits into the shared
``EnginePump`` and blocks on its own request's completion event. The
pump's scheduler is the single point of truth for admission control —
the server merely translates its outcomes onto the wire:

  ``POST /v1/generate``  LM prefill+decode   {"tokens": [...]} -> {"tokens": [[...], ...]}
  ``POST /v1/score``     recsys scoring      {"hist": [...], "candidates": [...]} -> {"scores": [...]}
  ``GET  /healthz``      readiness: 200 ok / 503 degraded-draining-unhealthy
  ``GET  /metrics``      per-engine ``ServeMetrics.snapshot()`` + gateway internals

Error mapping (see ``gateway.errors``): admission-control rejects,
deadline sheds, and open circuit breakers answer **503** with a
``Retry-After`` hint — the backpressure signal the client's bounded
exponential backoff keys on; caller-budget expiry answers 504; an engine
fault answers 500. Request bodies may carry ``deadline_ms`` (queue
deadline, defaults to the scheduler's) and ``timeout_s`` (caller wait
budget).

Resilience layers on the request path (each defaults on, each optional):

- **supervision** — a ``PumpSupervisor`` per pump restarts dead/wedged
  pump threads with backoff; ``/healthz`` answers 503 while any pump
  thread is dead or crash-looping (previously a dead pump kept reporting
  healthy while every request timed out);
- **circuit breaker** — per route: ``failure_threshold`` consecutive
  engine 500s open it, requests then shed immediately with 503 +
  Retry-After (= remaining cooldown), a half-open probe closes it on the
  first success;
- **idempotency dedupe** — POSTs carrying an ``Idempotency-Key`` header
  are deduplicated through a bounded LRU: a retry of an already-executed
  request replays the recorded outcome (marked ``"idempotent_replay"``)
  instead of double-executing; a retry racing the original blocks on its
  completion. Retryable outcomes (503) are not pinned — a later retry
  re-executes against hopefully-better conditions;
- **warm-restart snapshots** — with ``snapshot_dir`` set, ``stop()``
  saves each engine's GRASP cache state (``serve.cache.snapshot()``) and
  ``start()`` restores it, so a restarted gateway recovers its pre-crash
  hit rate instead of re-paying cold-start misses. A corrupt/mismatched
  snapshot is discarded (cold start), never trusted.

``stop()`` is the graceful-drain protocol: mark draining (new requests are
rejected with 503), stop the supervisors (shutdown is not a crash), then
``close()`` every pump (stop admissions, finish in-flight batches, join
the pump thread), snapshot the caches, and shut the listener down.
"""
from __future__ import annotations

import collections
import json
import os
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Dict, Optional, Tuple

import numpy as np

from repro.gateway.breaker import CircuitBreaker
from repro.gateway.errors import Failed, GatewayError, Rejected, Unavailable
from repro.gateway.pump import EnginePump
from repro.gateway.supervisor import PumpSupervisor


class _BadRequest(Exception):
    """Malformed request body — answered with 400, never enters the pump."""


class _HTTPServer(ThreadingHTTPServer):
    daemon_threads = True
    # socketserver's default listen backlog is 5 — an open-loop arrival
    # burst would see connection resets before admission control ever runs
    request_queue_size = 1024
    gateway: "GatewayServer"


class _IdemEntry:
    """One in-flight or completed idempotent request."""

    __slots__ = ("event", "response")

    def __init__(self) -> None:
        self.event = threading.Event()
        self.response: Optional[Tuple[int, Dict, Dict]] = None


class IdempotencyCache:
    """Bounded LRU of idempotency-keyed outcomes.

    ``begin`` either registers the caller as the *primary* executor for a
    key or hands back the existing entry (a duplicate: the same logical
    request re-sent after a connection reset). Duplicates wait on the
    primary's completion event and replay its recorded ``(code, body,
    headers)``. Outcomes the client is expected to retry (503) are
    dropped after resolution — pinning a shed under its key would turn
    every retry into a replay of the shed forever.
    """

    def __init__(self, maxsize: int = 512) -> None:
        self.maxsize = int(maxsize)
        self._entries: "collections.OrderedDict[str, _IdemEntry]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.replays = 0             # duplicate requests served from cache

    def begin(self, key: str) -> Tuple[str, _IdemEntry]:
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                self._entries.move_to_end(key)
                self.replays += 1
                return "dup", entry
            entry = _IdemEntry()
            self._entries[key] = entry
            while len(self._entries) > self.maxsize:
                # evict the oldest *completed* entry; in-flight entries are
                # skipped (their primaries still need to resolve them)
                for k, e in self._entries.items():
                    if e.event.is_set():
                        del self._entries[k]
                        break
                else:
                    break
            return "primary", entry

    def resolve(self, key: str, entry: _IdemEntry,
                code: int, body: Dict, headers: Dict) -> None:
        entry.response = (code, body, headers)
        entry.event.set()
        if code == 503:              # retryable: the retry must re-execute
            with self._lock:
                if self._entries.get(key) is entry:
                    del self._entries[key]

    def stats(self) -> Dict:
        with self._lock:
            return {"entries": len(self._entries), "replays": self.replays}


class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"
    server: _HTTPServer

    def log_message(self, fmt, *args):  # quiet: metrics cover observability
        pass

    def _send_json(self, code: int, obj: Dict,
                   headers: Optional[Dict[str, str]] = None) -> None:
        body = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self) -> None:
        gw = self.server.gateway
        if self.path == "/healthz":
            health = gw.health()
            self._send_json(200 if health["status"] == "ok" else 503, health)
        elif self.path == "/metrics":
            self._send_json(200, gw.metrics())
        else:
            self._send_json(404, {"error": "not_found", "detail": self.path})

    def _execute(self, gw: "GatewayServer", route) -> Tuple[int, Dict, Dict]:
        """Run one route; every outcome becomes a (code, body, headers)
        triple so it can be both sent and recorded for idempotent replay."""
        try:
            length = int(self.headers.get("Content-Length") or 0)
            obj = json.loads(self.rfile.read(length) or b"{}")
            if not isinstance(obj, dict):
                raise _BadRequest("body must be a JSON object")
            return 200, route(obj), {}
        except _BadRequest as e:
            return 400, {"error": "bad_request", "detail": str(e)}, {}
        except (json.JSONDecodeError, ValueError, TypeError) as e:
            return 400, {"error": "bad_request", "detail": str(e)}, {}
        except GatewayError as e:
            headers = {}
            if e.http_status == 503:
                headers["Retry-After"] = \
                    f"{e.retry_after_s or gw.retry_after_s:.3f}"
            return e.http_status, {"error": e.kind, "detail": str(e)}, headers
        except Exception as e:  # noqa: BLE001 — surface bugs as 500s
            return 500, {"error": "error", "detail": repr(e)}, {}

    def do_POST(self) -> None:
        gw = self.server.gateway
        route = gw.routes.get(self.path)
        if route is None:
            self._send_json(404, {"error": "not_found", "detail": self.path})
            return
        key = self.headers.get("Idempotency-Key")
        entry = None
        if key and gw.dedupe is not None:
            role, entry = gw.dedupe.begin(key)
            if role == "dup":
                # the original may still be executing: wait for its outcome
                if not entry.event.wait(gw.request_timeout_s + 5.0):
                    self._send_json(504, {"error": "timeout",
                                          "detail": "idempotent replay "
                                                    "timed out"})
                    return
                code, body, headers = entry.response
                self._send_json(code, dict(body, idempotent_replay=True),
                                headers)
                return
        code, body, headers = self._execute(gw, route)
        if entry is not None:
            gw.dedupe.resolve(key, entry, code, body, headers)
        self._send_json(code, body, headers)


class GatewayServer:
    """HTTP front-end over named engine pumps.

    ``pumps`` maps route names to pumps: ``"generate"`` mounts
    ``/v1/generate`` (an ``LMServeEngine``), ``"score"`` mounts
    ``/v1/score`` (a ``RecsysServeEngine``). ``port=0`` binds an ephemeral
    port — read it back from ``.address``/``.url`` (loopback tests).

    ``supervise``/``breaker``/``dedupe_size``/``snapshot_dir`` switch the
    resilience layers described in the module docstring;
    ``supervisor_config``/``breaker_config`` are kwargs forwarded to
    ``PumpSupervisor``/``CircuitBreaker``.
    """

    def __init__(
        self,
        pumps: Dict[str, EnginePump],
        host: str = "127.0.0.1",
        port: int = 0,
        request_timeout_s: float = 30.0,
        retry_after_s: float = 0.05,
        supervise: bool = True,
        supervisor_config: Optional[Dict] = None,
        breaker: bool = True,
        breaker_config: Optional[Dict] = None,
        dedupe_size: int = 512,
        snapshot_dir: Optional[str] = None,
    ) -> None:
        self.pumps = dict(pumps)
        self.request_timeout_s = float(request_timeout_s)
        self.retry_after_s = float(retry_after_s)
        self.snapshot_dir = snapshot_dir
        self.routes = {}
        if "generate" in self.pumps:
            self.routes["/v1/generate"] = self._generate
        if "score" in self.pumps:
            self.routes["/v1/score"] = self._score
        self.supervisors: Dict[str, PumpSupervisor] = {}
        if supervise:
            self.supervisors = {
                name: PumpSupervisor(pump, **(supervisor_config or {}))
                for name, pump in self.pumps.items()}
        self.breakers: Dict[str, CircuitBreaker] = {}
        if breaker:
            self.breakers = {name: CircuitBreaker(**(breaker_config or {}))
                             for name in self.pumps}
        self.dedupe = IdempotencyCache(dedupe_size) if dedupe_size else None
        self._draining = False
        self._httpd = _HTTPServer((host, port), _Handler)
        self._httpd.gateway = self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="gateway-http", daemon=True)

    # -- lifecycle -------------------------------------------------------
    @property
    def address(self) -> Tuple[str, int]:
        return self._httpd.server_address[:2]

    @property
    def url(self) -> str:
        host, port = self.address
        return f"http://{host}:{port}"

    def _snapshot_path(self, name: str) -> Optional[str]:
        if self.snapshot_dir is None:
            return None
        return os.path.join(self.snapshot_dir, f"{name}.cache.json")

    def _restore_snapshots(self) -> None:
        """Warm-start every engine that exposes a GRASP cache; a missing
        file is a silent cold start, a corrupt/mismatched one is discarded
        with the cold start noted in the engine's metrics."""
        from repro.serve.cache import SnapshotError

        for name, pump in self.pumps.items():
            path = self._snapshot_path(name)
            cache = getattr(pump.engine, "cache", None)
            if path is None or cache is None:
                continue
            try:
                cache.load_snapshot(path)
            except SnapshotError:
                pump.engine.metrics.count("snapshot_rejected")

    def _save_snapshots(self) -> None:
        for name, pump in self.pumps.items():
            path = self._snapshot_path(name)
            cache = getattr(pump.engine, "cache", None)
            if path is None or cache is None:
                continue
            os.makedirs(self.snapshot_dir, exist_ok=True)
            cache.save_snapshot(path)

    def start(self) -> "GatewayServer":
        self._restore_snapshots()
        for pump in self.pumps.values():
            pump.start()
        for sup in self.supervisors.values():
            sup.start()
        self._thread.start()
        return self

    def stop(self, drain_timeout_s: float = 30.0) -> None:
        """Graceful drain: reject new work, finish in-flight, snapshot the
        caches, shut down."""
        self._draining = True
        for sup in self.supervisors.values():
            sup.close()              # stand down first: shutdown != crash
        for pump in self.pumps.values():
            pump.close(drain_timeout_s)
        self._save_snapshots()
        if self._thread.ident is not None:   # shutdown() blocks forever if
            self._httpd.shutdown()           # serve_forever never started
            self._thread.join(5.0)
        self._httpd.server_close()

    def __enter__(self) -> "GatewayServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- introspection ---------------------------------------------------
    def health(self) -> Dict:
        """Readiness view: ``status == "ok"`` iff every started pump thread
        is alive and no supervisor is in a crash loop. The /healthz route
        maps any other status to HTTP 503."""
        engines = {}
        ready = True
        for name, pump in self.pumps.items():
            sup = self.supervisors.get(name)
            alive = pump.running
            dead = pump.started and not alive and not pump.draining
            crash_looping = sup is not None and not sup.healthy
            if dead or crash_looping:
                ready = False
            engines[name] = {
                "depth": pump.engine.batcher.depth,
                "draining": pump.draining,
                "running": alive,
                "alive": alive,
                "generation": pump.generation,
                "crashes": pump.crashes,
                "supervisor": sup.stats() if sup is not None else None,
            }
        status = ("draining" if self._draining
                  else "ok" if ready else "unhealthy")
        return {
            "status": status,
            "ready": status == "ok",
            "engines": engines,
            "breakers": {n: b.stats() for n, b in self.breakers.items()},
        }

    def metrics(self) -> Dict:
        out = {name: pump.engine.metrics.snapshot()
               for name, pump in self.pumps.items()}
        out["_gateway"] = {
            "dedupe": self.dedupe.stats() if self.dedupe else None,
            "breakers": {n: b.stats() for n, b in self.breakers.items()},
            "supervisors": {n: s.stats() for n, s in self.supervisors.items()},
        }
        return out

    # -- routes ----------------------------------------------------------
    def _budgets(self, obj: Dict) -> Tuple[Optional[float], float]:
        deadline_ms = obj.get("deadline_ms")
        deadline_s = None if deadline_ms is None else float(deadline_ms) / 1e3
        return deadline_s, float(obj.get("timeout_s", self.request_timeout_s))

    def _call(self, name: str, payload: Dict, obj: Dict):
        if self._draining:
            raise Rejected("gateway draining")
        pump = self.pumps[name]
        sup = self.supervisors.get(name)
        if sup is not None and not sup.healthy:
            raise Unavailable(f"{name}: pump crash-looping, shedding")
        deadline_s, timeout_s = self._budgets(obj)
        br = self.breakers.get(name)
        if br is not None:
            br.before()
        try:
            out = pump.call(payload, deadline_s=deadline_s, timeout=timeout_s)
        except Failed:
            if br is not None:
                br.record_failure()
            raise
        except GatewayError:             # backpressure/timeout: the scheduler
            if br is not None:           # doing its job, not an engine fault
                br.record_neutral()
            raise
        except Exception:
            if br is not None:
                br.record_failure()
            raise
        if br is not None:
            br.record_success()
        return out

    def _score(self, obj: Dict) -> Dict:
        cfg = self.pumps["score"].engine.cfg
        hist = np.asarray(obj.get("hist", []), dtype=np.int64).ravel()
        cand = np.asarray(obj.get("candidates", []), dtype=np.int64).ravel()
        if hist.size == 0 or cand.size == 0:
            raise _BadRequest("'hist' and 'candidates' are required")
        for name, ids in (("hist", hist), ("candidates", cand)):
            if ids.min() < 0 or ids.max() >= cfg.n_items:
                raise _BadRequest(
                    f"'{name}' ids must be in [0, {cfg.n_items})")
        h = hist[-cfg.hist_len:]
        full = np.zeros(cfg.hist_len, np.int32)
        mask = np.zeros(cfg.hist_len, bool)
        full[: h.size] = h
        mask[: h.size] = True
        if "hist_mask" in obj:
            m = np.asarray(obj["hist_mask"], dtype=bool).ravel()[-cfg.hist_len:]
            mask[: m.size] &= m[: m.size]
        payload = {"hist": full, "hist_mask": mask,
                   "candidates": cand.astype(np.int32)}
        scores = self._call("score", payload, obj)
        return {"scores": np.asarray(scores, np.float64).tolist()}

    def _generate(self, obj: Dict) -> Dict:
        toks = obj.get("tokens")
        if not toks or not isinstance(toks, list):
            raise _BadRequest("'tokens' must be a non-empty list of ids")
        payload = {"tokens": np.asarray(toks, np.int32)}
        out = self._call("generate", payload, obj)
        return {"tokens": np.asarray(out, np.int64).tolist()}
