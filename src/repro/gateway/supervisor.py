"""Pump supervision: liveness watchdog, restart-with-backoff, crash-loop
containment.

The pump absorbs *forward* faults (a raising forward fails its batch and
the loop continues) but not faults in the loop itself: a ``next_batch``
that raises kills the thread, and a forward that never returns wedges it.
Either way the queue stops draining while ``/healthz`` — without this
module — keeps reporting healthy. ``PumpSupervisor`` closes that gap:

- **heartbeat**: every pump loop iteration stamps ``pump.last_beat``; the
  watchdog thread samples it every ``check_interval_s``.
- **dead pump** (thread not alive, pump started, not closed): any claimed
  in-flight batch is failed out so its callers unblock with a typed 500,
  then the pump is restarted (``pump.restart()`` — fresh thread, bumped
  generation) after an exponential backoff ``backoff_s * factor^k``
  capped at ``backoff_cap_s``, where ``k`` counts restarts inside the
  current crash window.
- **wedged pump** (alive but one batch in flight longer than
  ``wedge_timeout_s``): the batch is failed out and a new generation is
  spawned; the wedged thread exits on its own if it ever unwedges
  (late ``complete``/``fail`` calls are no-ops on terminal requests).
- **crash loop**: more than ``crash_loop_threshold`` restarts within
  ``crash_loop_window_s`` trips the supervisor into ``healthy == False``.
  Restarts continue at the capped backoff (the engine may yet recover),
  but the gateway surfaces the state as 503 on ``/healthz`` readiness and
  sheds the route via ``Unavailable`` — a persistently dying engine must
  fail fast for callers, not burn pump restarts per request.

The supervisor never touches a pump that was never started and stands
down as soon as the pump is draining/closed (shutdown is not a crash).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.gateway.errors import Failed
from repro.gateway.pump import EnginePump


class PumpSupervisor:
    """Watchdog thread over one ``EnginePump``."""

    def __init__(
        self,
        pump: EnginePump,
        check_interval_s: float = 0.01,
        wedge_timeout_s: float = 30.0,
        backoff_s: float = 0.02,
        backoff_factor: float = 2.0,
        backoff_cap_s: float = 1.0,
        crash_loop_threshold: int = 5,
        crash_loop_window_s: float = 30.0,
    ) -> None:
        self.pump = pump
        self.check_interval_s = float(check_interval_s)
        self.wedge_timeout_s = float(wedge_timeout_s)
        self.backoff_s = float(backoff_s)
        self.backoff_factor = float(backoff_factor)
        self.backoff_cap_s = float(backoff_cap_s)
        self.crash_loop_threshold = int(crash_loop_threshold)
        self.crash_loop_window_s = float(crash_loop_window_s)
        self.restarts = 0             # total successful pump restarts
        self.deaths = 0               # dead-thread detections
        self.wedges = 0               # wedged-batch takeovers
        self.last_error: Optional[str] = None
        self._restart_times: List[float] = []   # for the crash-loop window
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._metrics = getattr(pump.engine, "metrics", None)

    # -- lifecycle -------------------------------------------------------
    def start(self) -> "PumpSupervisor":
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._watch, name=f"supervisor-{self.pump.name}",
                daemon=True)
            self._thread.start()
        return self

    def close(self, timeout: float = 5.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)

    def __enter__(self) -> "PumpSupervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- state -----------------------------------------------------------
    @property
    def healthy(self) -> bool:
        """False once the pump is crash-looping (restart budget exceeded
        inside the window). Recovers automatically when the window drains."""
        now = time.monotonic()
        recent = [t for t in self._restart_times
                  if now - t <= self.crash_loop_window_s]
        return len(recent) <= self.crash_loop_threshold

    def stats(self) -> Dict:
        return {
            "restarts": self.restarts,
            "deaths": self.deaths,
            "wedges": self.wedges,
            "healthy": self.healthy,
            "last_error": self.last_error,
        }

    # -- watchdog --------------------------------------------------------
    def _count(self, name: str) -> None:
        if self._metrics is not None:
            self._metrics.count(name)

    def _fail_out_inflight(self, why: str) -> None:
        batch = list(self.pump._inflight)
        if batch:
            self.pump.engine.batcher.fail(
                batch, Failed(f"{self.pump.name}: {why}"))

    def _backoff(self) -> float:
        k = len(self._restart_times)
        return min(self.backoff_cap_s, self.backoff_s * self.backoff_factor ** k)

    def _restart(self, why: str) -> None:
        self.last_error = why
        now = time.monotonic()
        self._restart_times = [t for t in self._restart_times
                               if now - t <= self.crash_loop_window_s]
        # exponential backoff before the respawn; interruptible by close()
        if self._stop.wait(self._backoff()):
            return
        if self.pump.restart():
            self.restarts += 1
            self._restart_times.append(time.monotonic())
            self._count("pump_restarts")
            if not self.healthy:
                self._count("pump_crash_loops")

    def _watch(self) -> None:
        pump = self.pump
        while not self._stop.wait(self.check_interval_s):
            if not pump.started or pump.draining:
                continue   # never-started pumps and shutdowns are not crashes
            if not pump.running:
                self.deaths += 1
                self._count("pump_deaths")
                cause = repr(pump.crash) if pump.crash else "thread died"
                # a death inside next_batch leaves the batch unclaimed, but a
                # thread killed mid-forward would strand its claimed batch
                self._fail_out_inflight(f"pump died ({cause})")
                self._restart(cause)
            elif pump.busy_for_s > self.wedge_timeout_s:
                self.wedges += 1
                self._count("pump_wedges")
                self._fail_out_inflight(
                    f"batch wedged > {self.wedge_timeout_s}s")
                self._restart(f"wedged > {self.wedge_timeout_s}s")
