"""Compressed Sparse Row graph representation.

The CSR encodes *in-edges* for pull-based computation (paper Sec. II-B):
``indptr[v] : indptr[v+1]`` is the slice of ``indices`` holding the source
vertex ids of v's in-edges. For push-based computation the same structure
encodes out-edges (sources become destinations); :func:`transpose` converts
between the two.

Arrays are plain numpy on the host; :meth:`CSR.device` returns a jnp pytree
for use inside jitted compute.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class CSR:
    """In-edge CSR. ``indices[indptr[v]:indptr[v+1]]`` = in-neighbours of v."""

    indptr: np.ndarray   # (num_nodes + 1,) int64
    indices: np.ndarray  # (num_edges,) int32 — source vertex of each in-edge
    num_nodes: int
    weights: Optional[np.ndarray] = None  # (num_edges,) float32, optional

    @property
    def num_edges(self) -> int:
        return int(self.indices.shape[0])

    @property
    def in_degree(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    @property
    def out_degree(self) -> np.ndarray:
        return np.bincount(self.indices, minlength=self.num_nodes).astype(np.int64)

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_nodes, 1)

    def dst_ids(self) -> np.ndarray:
        """Destination vertex id of every edge, aligned with ``indices``."""
        return np.repeat(
            np.arange(self.num_nodes, dtype=np.int32), np.diff(self.indptr)
        )

    def device(self) -> "DeviceCSR":
        return DeviceCSR(
            indptr=jnp.asarray(self.indptr, dtype=jnp.int32),
            indices=jnp.asarray(self.indices, dtype=jnp.int32),
            dst=jnp.asarray(self.dst_ids(), dtype=jnp.int32),
            weights=(
                jnp.asarray(self.weights, dtype=jnp.float32)
                if self.weights is not None
                else None
            ),
            num_nodes=self.num_nodes,
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeviceCSR:
    """Edge-list view for jitted segment ops (COO with CSR ordering)."""

    indptr: jnp.ndarray
    indices: jnp.ndarray  # source of each edge
    dst: jnp.ndarray      # destination of each edge (same order)
    weights: Optional[jnp.ndarray]
    num_nodes: int = dataclasses.field(metadata=dict(static=True))


def from_edges(
    src: np.ndarray,
    dst: np.ndarray,
    num_nodes: int,
    weights: Optional[np.ndarray] = None,
    dedup: bool = True,
) -> CSR:
    """Build an in-edge CSR from (src, dst) edge endpoints."""
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    keep = (src != dst)  # drop self loops
    src, dst = src[keep], dst[keep]
    if weights is not None:
        weights = np.asarray(weights, dtype=np.float32)[keep]
    if dedup:
        key = dst * num_nodes + src
        _, uniq = np.unique(key, return_index=True)
        src, dst = src[uniq], dst[uniq]
        if weights is not None:
            weights = weights[uniq]
    order = np.argsort(dst, kind="stable")
    src, dst = src[order], dst[order]
    if weights is not None:
        weights = weights[order]
    counts = np.bincount(dst, minlength=num_nodes)
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(
        indptr=indptr,
        indices=src.astype(np.int32),
        num_nodes=num_nodes,
        weights=weights,
    )


def transpose(g: CSR) -> CSR:
    """Swap edge direction (in-edge CSR <-> out-edge CSR)."""
    return from_edges(g.dst_ids(), g.indices, g.num_nodes, g.weights, dedup=False)


def symmetrize(g: CSR) -> CSR:
    src, dst = g.indices, g.dst_ids()
    return from_edges(
        np.concatenate([src, dst]),
        np.concatenate([dst, src]),
        g.num_nodes,
        dedup=True,
    )


def apply_reorder(g: CSR, rank: np.ndarray) -> CSR:
    """Renumber vertices: old vertex v becomes new vertex ``rank[v]``.

    ``rank`` must be a permutation of 0..N-1. Property arrays indexed by new
    vertex id must be built as ``prop_new[rank] = prop_old`` by the caller.
    """
    rank = np.asarray(rank, dtype=np.int64)
    assert rank.shape[0] == g.num_nodes
    new_src = rank[g.indices]
    new_dst = rank[g.dst_ids()]
    return from_edges(new_src, new_dst, g.num_nodes, g.weights, dedup=False)
