"""Scaled stand-ins for the paper's graph datasets (paper Table V).

Real datasets span 68M–2.1B edges; this container is CPU-only, so each
dataset is represented by an RMAT/uniform graph whose *skew statistics*
(hot-vertex fraction, edge coverage — paper Table I) match the original's
regime, at a scale where full app + LLC-simulation runs finish in seconds.
The LLC size used by the simulator is scaled by the same footprint ratio
(see ``scaled_llc_bytes``), keeping the paper's "hot footprint exceeds LLC"
operating point.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache

from repro.graph.csr import CSR
from repro.graph import generate

# Paper Table V originals, for footprint-ratio scaling.
PAPER_DATASETS = {
    "lj": dict(vertices=5_000_000, avg_degree=14),
    "pl": dict(vertices=43_000_000, avg_degree=15),
    "tw": dict(vertices=62_000_000, avg_degree=24),
    "kr": dict(vertices=67_000_000, avg_degree=20),
    "sd": dict(vertices=95_000_000, avg_degree=20),
    "fr": dict(vertices=64_000_000, avg_degree=33),
    "uni": dict(vertices=50_000_000, avg_degree=20),
}

PAPER_LLC_BYTES = 16 * 1024 * 1024  # simulated system, paper Table VI


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    kind: str        # rmat | rmat_mild | uniform
    scale: int       # log2 num vertices (scaled-down)
    avg_degree: int
    seed: int


# Scaled specs: high-skew five + low-skew fr + no-skew uni.
SPECS = {
    "lj": DatasetSpec("lj", "rmat", 15, 14, 1),
    "pl": DatasetSpec("pl", "rmat", 16, 15, 2),
    "tw": DatasetSpec("tw", "rmat", 16, 24, 3),
    "kr": DatasetSpec("kr", "rmat", 16, 20, 4),
    "sd": DatasetSpec("sd", "rmat", 16, 20, 5),
    "fr": DatasetSpec("fr", "rmat_mild", 16, 24, 6),   # low skew
    "uni": DatasetSpec("uni", "uniform", 16, 20, 7),   # no skew
}

HIGH_SKEW = ("lj", "pl", "tw", "kr", "sd")
ADVERSARIAL = ("fr", "uni")


@lru_cache(maxsize=None)
def load(name: str, scale: int | None = None) -> CSR:
    spec = SPECS[name]
    s = spec.scale if scale is None else scale
    if spec.kind == "rmat":
        return generate.rmat(s, spec.avg_degree, seed=spec.seed)
    if spec.kind == "rmat_mild":
        # milder RMAT parameters -> low skew (friendster-like)
        return generate.rmat(s, spec.avg_degree, a=0.45, b=0.22, c=0.22, seed=spec.seed)
    if spec.kind == "uniform":
        return generate.uniform(s, spec.avg_degree, seed=spec.seed)
    raise ValueError(spec.kind)


def scaled_llc_bytes(name: str, g: CSR, elem_bytes: int = 8) -> int:
    """Scale the 16MB paper LLC by the property-footprint ratio.

    paper_footprint / 16MB == our_footprint / our_llc, so the thrash regime
    (property array >> LLC, hot region ~ LLC) is preserved.
    """
    paper = PAPER_DATASETS[name]
    paper_footprint = paper["vertices"] * elem_bytes
    ratio = paper_footprint / PAPER_LLC_BYTES
    ours = int(g.num_nodes * elem_bytes / ratio)
    # round down to a power of two >= 16KB so set count stays a power of 2
    size = 16 * 1024
    while size * 2 <= ours:
        size *= 2
    return size
