"""Synthetic graph generators.

The paper evaluates on large natural (power-law) graphs. Real datasets
(68M–2B edges) are out of scope for a CPU container, so we generate scaled
RMAT graphs (Chakrabarti et al., SDM'04 — the paper's ``kr``/``uni``
citations) that preserve the skew statistics the paper depends on
(Table I: 9–26% hot vertices covering 81–93% of edges).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSR, from_edges


def rmat(
    scale: int,
    avg_degree: int,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
    seed: int = 0,
) -> CSR:
    """Graph500-style RMAT generator, fully vectorized.

    ``scale`` = log2(num_nodes); default (a,b,c,d) are the Graph500
    parameters yielding a high-skew power-law degree distribution.
    """
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * avg_degree
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    ab = a + b
    a_norm = a / ab
    c_norm = c / (1.0 - ab)
    for bit in range(scale):
        go_right_src = rng.random(m) > ab  # choose bottom half for src bit
        p_dst = np.where(go_right_src, c_norm, a_norm)
        go_right_dst = rng.random(m) > (1.0 - p_dst)  # bottom half for dst
        src |= go_right_src.astype(np.int64) << bit
        dst |= go_right_dst.astype(np.int64) << bit
    # permute vertex labels so degree is NOT correlated with vertex id —
    # this mirrors real datasets where hot vertices are scattered in the id
    # space (the paper's "lack of spatial locality" problem).
    perm = rng.permutation(n)
    return from_edges(perm[src], perm[dst], n)


def uniform(scale: int, avg_degree: int, seed: int = 0) -> CSR:
    """Uniform-random (no-skew) graph — the paper's adversarial ``uni``."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * avg_degree
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return from_edges(src, dst, n)


def add_uniform_weights(g: CSR, seed: int = 0, low: float = 1.0, high: float = 64.0) -> CSR:
    rng = np.random.default_rng(seed)
    w = rng.uniform(low, high, g.num_edges).astype(np.float32)
    return CSR(indptr=g.indptr, indices=g.indices, num_nodes=g.num_nodes, weights=w)


def two_level_example() -> CSR:
    """The paper's Fig. 1 example graph (6 vertices), for unit tests."""
    # edges (src -> dst) as drawn: P2 and P5 are the high out-degree hubs.
    edges = [
        (2, 1), (5, 1), (0, 1),
        (2, 3), (5, 3), (4, 3),
        (1, 0), (2, 0),
        (5, 4), (2, 4),
        (3, 5), (0, 5),
        (5, 2),
    ]
    src = np.array([e[0] for e in edges])
    dst = np.array([e[1] for e in edges])
    return from_edges(src, dst, 6)
