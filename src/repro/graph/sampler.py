"""Fanout neighbour sampler (GraphSAGE-style) for ``minibatch_lg``.

Host-side numpy sampling producing fixed-shape (padded + masked) subgraph
arrays suitable for jit: seeds (B,), per-level sampled neighbours with
fanouts (15, 10). Local node ids: [seeds | level-1 | level-2] so the edge
arrays are statically shaped.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSR


@dataclasses.dataclass(frozen=True)
class SampledBlocks:
    node_ids: np.ndarray   # (n_sub,) global ids (padded with 0)
    node_mask: np.ndarray  # (n_sub,) valid
    src: np.ndarray        # (E_sub,) local ids
    dst: np.ndarray        # (E_sub,) local ids
    emask: np.ndarray      # (E_sub,)
    seeds_local: np.ndarray  # (B,) local ids of the seed nodes (= arange(B))

    @property
    def n_sub(self) -> int:
        return int(self.node_ids.shape[0])


def subgraph_shape(batch_nodes: int, fanout: tuple[int, ...]) -> tuple[int, int]:
    """(n_sub, e_sub) static shapes for a fanout spec."""
    n = batch_nodes
    total_nodes, total_edges, width = n, 0, n
    for f in fanout:
        width *= f
        total_nodes += width
        total_edges += width
    return total_nodes, total_edges


def sample_blocks(g: CSR, seeds: np.ndarray, fanout: tuple[int, ...],
                  rng: np.random.Generator) -> SampledBlocks:
    """Uniform neighbour sampling, fixed fanout with padding (repeat-sample
    when degree < fanout, mask when degree == 0)."""
    indptr, indices = g.indptr, g.indices
    frontier = seeds.astype(np.int64)
    frontier_mask = np.ones_like(frontier, dtype=bool)
    all_nodes = [frontier]
    all_masks = [frontier_mask]
    srcs, dsts, emasks = [], [], []
    offset = 0  # local id offset of the current frontier

    for f in fanout:
        deg = indptr[frontier + 1] - indptr[frontier]
        # sample f neighbours per frontier node (with replacement)
        r = rng.integers(0, 2**31 - 1, size=(frontier.shape[0], f))
        has_nbr = (deg > 0) & frontier_mask
        pick = np.where(
            has_nbr[:, None], indptr[frontier][:, None] + r % np.maximum(deg, 1)[:, None], 0
        )
        nbr = np.where(has_nbr[:, None], indices[pick], 0).reshape(-1)
        nbr_mask = np.repeat(has_nbr, f)
        # edges: sampled neighbour (src, local) -> frontier node (dst, local)
        next_offset = offset + frontier.shape[0]
        src_local = next_offset + np.arange(nbr.shape[0])
        dst_local = offset + np.repeat(np.arange(frontier.shape[0]), f)
        srcs.append(src_local)
        dsts.append(dst_local)
        emasks.append(nbr_mask)
        all_nodes.append(nbr)
        all_masks.append(nbr_mask)
        frontier = nbr
        frontier_mask = nbr_mask
        offset = next_offset

    return SampledBlocks(
        node_ids=np.concatenate(all_nodes).astype(np.int32),
        node_mask=np.concatenate(all_masks),
        src=np.concatenate(srcs).astype(np.int32),
        dst=np.concatenate(dsts).astype(np.int32),
        emask=np.concatenate(emasks),
        seeds_local=np.arange(seeds.shape[0], dtype=np.int32),
    )
