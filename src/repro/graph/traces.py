"""LLC access-trace generation from graph-application iterations.

Models the paper's Sec. II-C access anatomy for one pull (or push) ROI
iteration: for every active destination vertex the engine streams the
Vertex Array entry, touches the destination's Property element, then for
each in-edge streams the Edge Array entry and gathers the source vertex's
Property element. An L1-filter drops consecutive same-line accesses per
instruction stream (the paper notes the streaming arrays' spatial locality
is filtered by L1-D, leaving streaming/irregular patterns at the LLC).

Synthetic PC signatures (paper's Hawkeye/Leeway analysis hinges on the same
PC touching hot and cold vertices alike):
  pc 0 = source-property gather   (the irregular hot path)
  pc 1 = Edge Array stream
  pc 2 = Vertex Array stream
  pc 3 = destination-property access
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.plan import GraspPlan, make_plan
from repro.core.regions import DEFAULT
from repro.graph.csr import CSR, transpose
from repro.core.cachesim import Trace, finalize_trace

LINE = 64


@dataclasses.dataclass(frozen=True)
class AppTraceSpec:
    """Trace-shape parameters per paper application (Tables III & IV)."""

    name: str
    direction: str          # dominant ROI direction (paper Sec. IV-C)
    active_fraction: float  # fraction of vertices active in the ROI iteration
    elem_bytes: int         # Property element size after array merging
    num_prop_arrays: int    # arrays GRASP must track (paper: at most two)


APPS = {
    "pr": AppTraceSpec("pr", "pull", 1.0, 16, 1),      # merged rank pair
    "prd": AppTraceSpec("prd", "pull", 0.45, 16, 1),   # delta-active subset
    "sssp": AppTraceSpec("sssp", "push", 0.35, 8, 1),  # Bellman-Ford push
    "bc": AppTraceSpec("bc", "pull", 0.6, 16, 2),      # BFS kernel + sigma
    "radii": AppTraceSpec("radii", "pull", 1.0, 8, 2), # 64-bit visit masks
}


def _l1_filter(line: np.ndarray, pc: np.ndarray) -> np.ndarray:
    """Keep mask dropping consecutive same-line accesses per PC stream."""
    keep = np.ones(line.shape[0], dtype=bool)
    for p in np.unique(pc):
        pos = np.nonzero(pc == p)[0]
        if pos.size > 1:
            keep[pos[1:]] = line[pos[1:]] != line[pos[:-1]]
    return keep


def generate_trace(
    g: CSR,
    app: str,
    llc_bytes: int,
    plan: Optional[GraspPlan] = None,
    seed: int = 0,
    hints_enabled: bool = True,
    max_records: int = 6_000_000,
) -> tuple[Trace, GraspPlan]:
    """Build the LLC trace of one ROI iteration of ``app`` over ``g``.

    ``g`` must already be reordered by the technique under test (the trace
    simply reflects whatever vertex placement it is given). Returns the
    trace and the GraspPlan used for hint classification.
    """
    spec = APPS[app]
    work = g if spec.direction == "pull" else transpose(g)
    n = work.num_nodes
    indptr, indices = work.indptr, work.indices

    if plan is None:
        plan = make_plan(n, spec.elem_bytes, budget_bytes=llc_bytes,
                         num_arrays=spec.num_prop_arrays)

    rng = np.random.default_rng(seed)
    if spec.active_fraction >= 1.0:
        act = np.arange(n, dtype=np.int64)
    else:
        mask = rng.random(n) < spec.active_fraction
        act = np.nonzero(mask)[0]

    deg = (indptr[act + 1] - indptr[act]).astype(np.int64)
    rec_per = 2 + 2 * deg
    if rec_per.sum() > max_records:  # cap ROI length, keep traversal prefix
        cut = np.searchsorted(np.cumsum(rec_per), max_records)
        act, deg, rec_per = act[:cut], deg[:cut], rec_per[:cut]
    total = int(rec_per.sum())
    starts = np.cumsum(rec_per) - rec_per

    prop_bytes = n * spec.elem_bytes
    edge_base = ((prop_bytes + LINE - 1) // LINE) * LINE
    vert_base = edge_base + ((work.num_edges * 4 + LINE - 1) // LINE) * LINE

    line = np.empty(total, dtype=np.int64)
    pc = np.empty(total, dtype=np.int8)

    # vertex-array + destination-property records at each row start
    line[starts] = (vert_base + act * 4) // LINE
    pc[starts] = 2
    line[starts + 1] = (act * spec.elem_bytes) // LINE
    pc[starts + 1] = 3

    # per-edge records
    row = np.repeat(np.arange(act.shape[0]), deg)
    k = np.arange(int(deg.sum()), dtype=np.int64) - np.repeat(
        np.cumsum(deg) - deg, deg
    )
    edge_global = np.repeat(indptr[act], deg) + k
    src = indices[edge_global].astype(np.int64)
    slot = starts[row] + 2 + 2 * k
    line[slot] = (edge_base + edge_global * 4) // LINE
    pc[slot] = 1
    line[slot + 1] = (src * spec.elem_bytes) // LINE
    pc[slot + 1] = 0

    keep = _l1_filter(line, pc)
    line, pc = line[keep], pc[keep]

    # GRASP hints: range classification of property addresses; everything
    # else in a graph app is Low-Reuse (paper Sec. III-B). hints_enabled
    # False models the "ABRs not set" default (non-graph application).
    if hints_enabled:
        byte_addr = line * LINE
        hint = plan.regions().classify(byte_addr)
        hint = np.where((pc == 1) | (pc == 2), np.int8(2), hint)
    else:
        hint = np.full(line.shape[0], DEFAULT, dtype=np.int8)

    return finalize_trace(line, hint, pc), plan
