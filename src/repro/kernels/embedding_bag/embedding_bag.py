"""Pallas TPU kernel: fused hot-cached EmbeddingBag (GRASP for recsys).

Item popularity is Zipfian, so with the table rows popularity-ordered (the
recsys analogue of DBG reordering) the leading ``hot_size`` rows cover the
overwhelming majority of lookups. Those rows are pinned as a constant VMEM
block; each grid step processes a tile of bags (batch rows), gathering and
summing the hot rows in one pass — gather + segment-reduce fused, zero HBM
traffic for hot lookups. Cold rows are fixed up by ops.py with a bounded
compacted HBM gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bag_kernel(ids_ref, mask_ref, hot_ref, out_ref, *, hot_size: int):
    ids = ids_ref[...]                       # (tile_b, H) int32
    mask = mask_ref[...]                     # (tile_b, H) bool
    hot = hot_ref[...]                       # (hot_size, d) pinned
    tile_b, hlen = ids.shape
    safe = jnp.clip(ids, 0, hot_size - 1)
    rows = jnp.take(hot, safe.reshape(-1), axis=0).reshape(tile_b, hlen, -1)
    hit = mask & (ids >= 0) & (ids < hot_size)
    out_ref[...] = (
        jnp.where(hit[..., None], rows, 0.0).sum(axis=1).astype(out_ref.dtype)
    )


@functools.partial(jax.jit, static_argnames=("tile_b", "interpret"))
def hot_bag_hot_part(
    hot_table: jnp.ndarray,    # (H_rows, d) pinned hot prefix
    ids: jnp.ndarray,          # (B, H) int32
    mask: jnp.ndarray,         # (B, H) bool
    tile_b: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    hr, d = hot_table.shape
    b, hlen = ids.shape
    assert b % tile_b == 0
    grid = (b // tile_b,)
    return pl.pallas_call(
        functools.partial(_bag_kernel, hot_size=hr),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_b, hlen), lambda i: (i, 0)),
            pl.BlockSpec((tile_b, hlen), lambda i: (i, 0)),
            pl.BlockSpec((hr, d), lambda i: (0, 0)),   # pinned across grid
        ],
        out_specs=pl.BlockSpec((tile_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, mask, hot_table)
