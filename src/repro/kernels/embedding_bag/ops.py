"""Jitted wrappers: hot-cached embedding lookup / bag with cold fixup."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import plan as plan_mod
from repro.core.plan import GraspPlan
from repro.kernels.embedding_bag.embedding_bag import hot_bag_hot_part
from repro.kernels.hot_gather.ops import hot_gather

LANE = 128


def hot_lookup(table: jnp.ndarray, ids: jnp.ndarray,
               plan: Optional[GraspPlan] = None, interpret: bool = True):
    """(V,d) x (B,) -> (B,d); hot prefix from VMEM, cold fixup bounded."""
    if plan is not None:
        hot_size = plan.hot_size
    else:
        # default: the VMEM-budget share of the table (== 2^18 rows at d=64)
        hot_size = plan_mod.entries_for_budget(
            int(plan_mod.VMEM_BYTES * plan_mod.DEFAULT_VMEM_FRACTION),
            table.shape[1] * table.dtype.itemsize,
            max_entries=table.shape[0],
        )
    return hot_gather(table, ids, hot_size=hot_size, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("hot_size", "cold_capacity",
                                             "tile_b", "interpret"))
def hot_bag(
    table: jnp.ndarray,       # (V, d)
    ids: jnp.ndarray,         # (B, H)
    mask: jnp.ndarray,        # (B, H)
    hot_size: int,
    cold_capacity: Optional[int] = None,
    tile_b: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused EmbeddingBag(sum): kernel handles hot rows; cold rows are
    compacted, gathered once from HBM and segment-summed into the bags."""
    v, d = table.shape
    b, hlen = ids.shape
    hot_size = min(hot_size, v)
    if cold_capacity is None:
        cold_capacity = b * hlen

    d_pad = (d + LANE - 1) // LANE * LANE
    b_pad = (b + tile_b - 1) // tile_b * tile_b
    hot = jnp.pad(table[:hot_size], ((0, 0), (0, d_pad - d)))
    ids_p = jnp.pad(ids, ((0, b_pad - b), (0, 0)), constant_values=-1)
    mask_p = jnp.pad(mask, ((0, b_pad - b), (0, 0)), constant_values=False)

    out = hot_bag_hot_part(hot, ids_p, mask_p, tile_b=tile_b,
                           interpret=interpret)[:b, :d]

    # cold fixup: compact cold (id, bag) pairs, gather, segment-sum per bag
    flat_ids = ids.reshape(-1)
    flat_mask = mask.reshape(-1)
    bag_of = jnp.repeat(jnp.arange(b), hlen)
    cold = flat_mask & (flat_ids >= hot_size)
    pos = jnp.cumsum(cold.astype(jnp.int32)) - 1
    slot = jnp.where(cold & (pos < cold_capacity), pos, cold_capacity)
    comp_ids = jnp.zeros((cold_capacity + 1,), flat_ids.dtype).at[slot].set(flat_ids)
    comp_bag = jnp.full((cold_capacity + 1,), b, bag_of.dtype).at[slot].set(bag_of)
    cold_rows = jnp.take(table, comp_ids[:cold_capacity], axis=0)
    fix = jax.ops.segment_sum(
        cold_rows, comp_bag[:cold_capacity], num_segments=b + 1
    )[:b]
    return out + fix
