"""Pure-jnp oracle for the hot-cached embedding bag."""
from __future__ import annotations

import jax.numpy as jnp


def lookup_ref(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """(V, d) table, (B,) ids -> (B, d)."""
    return jnp.take(table, ids, axis=0)


def bag_ref(table: jnp.ndarray, ids: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """EmbeddingBag(sum): (V,d) table, (B,H) ids + mask -> (B,d).

    JAX has no native EmbeddingBag; this gather + masked-sum is the
    reference semantics (torch ``nn.EmbeddingBag(mode='sum')``)."""
    rows = jnp.take(table, ids, axis=0)          # (B, H, d)
    return jnp.where(mask[..., None], rows, 0.0).sum(axis=1)
