"""Pallas TPU kernel: VMEM-pinned hot-region gather (GRASP, kernel tier).

The High Reuse Region (first ``hot_size`` rows of the DBG-reordered
Property Array) is mapped as a VMEM block whose index_map is constant —
the block is loaded from HBM once and stays resident across the whole grid
(the TPU-native analogue of "protected from thrashing"). Each grid step
gathers one tile of edge indices against the pinned table; indices outside
the hot region produce zeros and are fixed up by the cold path in ops.py.

TPU mapping notes:
  * d (feature width) is padded to a multiple of 128 (lane dim) by ops.py.
  * the row gather inside VMEM lowers to a vector gather on Mosaic
    (validated here with interpret=True on CPU; TPU is the target).
  * VMEM budget: hot_size*d*4B + tile buffers must fit ~16MB/core of
    usable VMEM per the GraspPlan (plan.budget_bytes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hot_gather_kernel(idx_ref, hot_ref, out_ref, *, hot_size: int):
    idx = idx_ref[...]                                   # (tile_e,) int32
    safe = jnp.clip(idx, 0, hot_size - 1)
    rows = jnp.take(hot_ref[...], safe, axis=0)          # VMEM vector gather
    hit = (idx >= 0) & (idx < hot_size)
    out_ref[...] = jnp.where(hit[:, None], rows, 0.0).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_e", "interpret"))
def hot_gather_hot_part(
    hot_table: jnp.ndarray,   # (H, d) — the pinned High Reuse Region
    idx: jnp.ndarray,         # (E,) int32, full index stream (hot + cold)
    tile_e: int = 2048,
    interpret: bool = True,   # CPU container: interpret; TPU: False
) -> jnp.ndarray:
    h, d = hot_table.shape
    e = idx.shape[0]
    assert e % tile_e == 0, f"E={e} must be divisible by tile_e={tile_e}"
    grid = (e // tile_e,)
    return pl.pallas_call(
        functools.partial(_hot_gather_kernel, hot_size=h),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_e,), lambda i: (i,)),      # index tile
            pl.BlockSpec((h, d), lambda i: (0, 0)),       # pinned hot block
        ],
        out_specs=pl.BlockSpec((tile_e, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((e, d), hot_table.dtype),
        interpret=interpret,
    )(idx, hot_table)


def _gather_seg_kernel(idx_ref, seg_ref, hot_ref, out_ref, *, hot_size: int,
                       seg_per_tile: int):
    """Fused gather + local segment-sum: edges are CSR-ordered, so each edge
    tile touches a bounded contiguous destination range handled as a local
    one-hot matmul (MXU-friendly) accumulated into the output tile."""
    i = pl.program_id(0)
    idx = idx_ref[...]
    seg = seg_ref[...]
    safe = jnp.clip(idx, 0, hot_size - 1)
    rows = jnp.take(hot_ref[...], safe, axis=0)
    hit = (idx >= 0) & (idx < hot_size)
    rows = jnp.where(hit[:, None], rows, 0.0)
    local_seg = seg - i * seg_per_tile
    onehot = (local_seg[None, :] == jnp.arange(seg_per_tile)[:, None]).astype(
        rows.dtype
    )
    out_ref[...] = jnp.dot(onehot, rows, preferred_element_type=jnp.float32).astype(
        out_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("num_segments", "tile_e", "seg_per_tile", "interpret")
)
def hot_gather_segment_sum(
    hot_table: jnp.ndarray,
    idx: jnp.ndarray,
    seg: jnp.ndarray,          # (E,) destination of each edge, sorted asc.
    num_segments: int,
    tile_e: int = 2048,
    seg_per_tile: int = 256,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused hot gather + segment-sum. Requires an aligned edge layout where
    tile i only holds edges with seg in [i*seg_per_tile, (i+1)*seg_per_tile)
    (built by ops.build_aligned_edges — padding with idx=-1)."""
    h, d = hot_table.shape
    e = idx.shape[0]
    grid = (e // tile_e,)
    assert grid[0] * seg_per_tile == num_segments
    return pl.pallas_call(
        functools.partial(
            _gather_seg_kernel, hot_size=h, seg_per_tile=seg_per_tile
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_e,), lambda i: (i,)),
            pl.BlockSpec((tile_e,), lambda i: (i,)),
            pl.BlockSpec((h, d), lambda i: (0, 0)),       # pinned hot block
        ],
        out_specs=pl.BlockSpec((seg_per_tile, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=interpret,
    )(idx, seg, hot_table)
