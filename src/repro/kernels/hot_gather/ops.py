"""Jitted wrappers composing the hot-region Pallas kernel with the bounded
cold-path fixup (the full GRASP two-tier gather).

Cold fixup: indices >= hot_size are compacted into a capacity-bounded
buffer (skew guarantees the cold fraction is small — paper Table I: hot
vertices cover 81-93% of edges), gathered from HBM once, and scattered
back. ``cold_capacity`` bounds the HBM traffic; on no-skew inputs callers
size it at E (graceful degradation, paper Fig. 9).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plan import GraspPlan
from repro.kernels.hot_gather.hot_gather import (
    hot_gather_hot_part,
    hot_gather_segment_sum,
)

LANE = 128


def _pad_rows(e: int, tile: int) -> int:
    return (e + tile - 1) // tile * tile


@functools.partial(jax.jit, static_argnames=("hot_size", "cold_capacity",
                                             "tile_e", "interpret"))
def hot_gather(
    prop: jnp.ndarray,         # (N, d)
    idx: jnp.ndarray,          # (E,) int32
    hot_size: Optional[int] = None,
    cold_capacity: Optional[int] = None,
    tile_e: int = 2048,
    interpret: bool = True,
) -> jnp.ndarray:
    """Drop-in replacement for ``jnp.take(prop, idx, axis=0)``."""
    n, d = prop.shape
    e = idx.shape[0]
    if hot_size is None:
        hot_size = min(n, 1 << 20)
    hot_size = min(hot_size, n)
    if cold_capacity is None:
        cold_capacity = e  # exact by default; plans shrink it via skew

    d_pad = (d + LANE - 1) // LANE * LANE
    e_pad = _pad_rows(e, tile_e)
    hot = jnp.pad(prop[:hot_size], ((0, 0), (0, d_pad - d)))
    idx_p = jnp.pad(idx, (0, e_pad - e), constant_values=-1)

    out = hot_gather_hot_part(hot, idx_p, tile_e=tile_e, interpret=interpret)
    out = out[:e, :d]

    # --- bounded cold fixup (HBM gather of the compacted cold indices) ---
    cold = idx >= hot_size
    pos = jnp.cumsum(cold.astype(jnp.int32)) - 1          # slot per cold idx
    slot = jnp.where(cold & (pos < cold_capacity), pos, cold_capacity)
    comp = jnp.zeros((cold_capacity + 1,), idx.dtype).at[slot].set(idx)
    cold_rows = jnp.take(prop, comp[:cold_capacity], axis=0)
    cold_rows = jnp.concatenate(
        [cold_rows, jnp.zeros((1, d), prop.dtype)], axis=0
    )
    fix = jnp.take(cold_rows, jnp.minimum(slot, cold_capacity), axis=0)
    return jnp.where(cold[:, None], fix, out)


def build_aligned_edges(indptr: np.ndarray, indices: np.ndarray,
                        seg_per_tile: int, tile_e: int):
    """Host-side layout pass: pack CSR edges into tiles such that tile i only
    contains destinations [i*seg_per_tile, (i+1)*seg_per_tile), padding with
    idx=-1. Returns (idx_tiles, seg_tiles, num_segments_padded)."""
    n = indptr.shape[0] - 1
    n_pad = (n + seg_per_tile - 1) // seg_per_tile * seg_per_tile
    n_tiles = n_pad // seg_per_tile
    out_idx, out_seg = [], []
    for t in range(n_tiles):
        lo_v, hi_v = t * seg_per_tile, min((t + 1) * seg_per_tile, n)
        sl = slice(indptr[lo_v], indptr[hi_v])
        e_idx = indices[sl]
        e_seg = np.repeat(
            np.arange(lo_v, hi_v), np.diff(indptr[lo_v : hi_v + 1])
        )
        # split oversized tiles into multiple chunks of tile_e
        for off in range(0, max(len(e_idx), 1), tile_e):
            chunk_i = e_idx[off : off + tile_e]
            chunk_s = e_seg[off : off + tile_e]
            pad = tile_e - len(chunk_i)
            out_idx.append(np.pad(chunk_i, (0, pad), constant_values=-1))
            out_seg.append(np.pad(chunk_s, (0, pad), constant_values=lo_v))
    return (
        np.concatenate(out_idx).astype(np.int32),
        np.concatenate(out_seg).astype(np.int32),
        n_pad,
    )


def hot_gather_segsum_aligned(
    hot_table: jnp.ndarray,
    idx_tiles: jnp.ndarray,
    seg_tiles: jnp.ndarray,
    num_segments: int,
    seg_per_tile: int,
    tile_e: int = 2048,
    interpret: bool = True,
) -> jnp.ndarray:
    """Fused hot gather + segment-sum over a pre-aligned edge layout.

    Multiple tiles may map to the same output block (oversized vertex
    ranges); pallas accumulates via the revisiting-output pattern only when
    the grid is ordered, so we instead sum duplicate tiles outside: callers
    with heavy-hub tiles use ops.hot_gather + segment_sum. This fused path
    asserts one tile per segment block.
    """
    d_pad = (hot_table.shape[1] + LANE - 1) // LANE * LANE
    hot = jnp.pad(hot_table, ((0, 0), (0, d_pad - hot_table.shape[1])))
    out = hot_gather_segment_sum(
        hot, idx_tiles, seg_tiles, num_segments,
        tile_e=tile_e, seg_per_tile=seg_per_tile, interpret=interpret,
    )
    return out[:, : hot_table.shape[1]]
