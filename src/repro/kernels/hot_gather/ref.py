"""Pure-jnp oracle for the two-tier hot gather."""
from __future__ import annotations

import jax.numpy as jnp


def gather_ref(prop: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """(N, d) table, (E,) indices -> (E, d). The semantics the fused
    hot/cold path must reproduce exactly."""
    return jnp.take(prop, idx, axis=0)


def gather_segment_sum_ref(
    prop: jnp.ndarray, idx: jnp.ndarray, seg: jnp.ndarray, num_segments: int
) -> jnp.ndarray:
    """Fused gather + destination segment-sum (the pull-engine hot path)."""
    import jax

    return jax.ops.segment_sum(jnp.take(prop, idx, axis=0), seg, num_segments)
