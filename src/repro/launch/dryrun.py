import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh, prove memory fits, extract roofline terms.

MUST be run as its own process (the XLA_FLAGS line above precedes every
other import — jax locks the device count on first init):

    PYTHONPATH=src python -m repro.launch.dryrun --mesh single --cells all
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi  --cells lm

Results land in reports/dryrun_<mesh>.json (one record per cell: status,
bytes per device, HLO flops/bytes, collective bytes by op, roofline terms).
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import base as cfgs
from repro.dist import sharding as shd
from repro.launch import roofline as rl
from repro.launch import steps as steps_mod
from repro.launch.mesh import make_production_mesh


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str) -> dict:
    cell = steps_mod.build_cell(arch, shape_name, mesh)
    cfg = cfgs.get_arch(arch)
    shape = cfgs.SHAPES[cfg.family][shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name}
    t0 = time.time()
    try:
        shd.set_active_mesh(mesh)
        with jax.set_mesh(mesh):
            jitted = jax.jit(
                cell.step_fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate,
            )
            lowered = jitted.lower(*cell.args)
            compiled = lowered.compile()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # older jax: one dict per program
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        bytes_per_dev = None
        if mem is not None:
            bytes_per_dev = (
                getattr(mem, "argument_size_in_bytes", 0)
                + getattr(mem, "output_size_in_bytes", 0)
                + getattr(mem, "temp_size_in_bytes", 0)
            )
        analytic = None
        loop_trips = ()
        if cfg.family == "lm":
            # XLA counts scan bodies once: LM compute/memory terms come from
            # the analytic model; collectives get while-depth trip scaling
            # (microbatch+layer scans, then the attention kv-chunk scan).
            inner = max(shape.seq_len // 1024, 1)
            mbs = max(min(cfg.microbatches,
                          shape.global_batch
                          // (mesh.size // mesh.shape["model"])), 1)
            # nesting: [microbatch scan] -> [group scan ->] layer scan
            #          -> kv-chunk scan
            groups = getattr(cfg, "layer_groups", 1)
            layer_levels = ((groups, cfg.n_layers // groups)
                            if groups > 1 else (cfg.n_layers,))
            if shape.kind == "train" and mbs > 1:
                loop_trips = (mbs,) + layer_levels + (inner,)
            else:
                loop_trips = layer_levels + (inner,)
            analytic = rl.analytic_lm_terms(
                cfg, shape, mesh.size, n_model=mesh.shape["model"]
            )
        roof = rl.analyze(
            arch, shape_name, mesh_name, mesh.size, cost, hlo,
            model_flops=rl.model_flops_for(cfg, shape),
            memory_bytes=bytes_per_dev,
            loop_trips=loop_trips, analytic=analytic,
        )
        rec.update(status="ok", compile_s=round(time.time() - t0, 1),
                   **roof.row())
        print(f"[dryrun] OK  {arch:24s} {shape_name:14s} {mesh_name:6s} "
              f"compile={rec['compile_s']:6.1f}s dominant={roof.dominant:10s} "
              f"bytes/dev={bytes_per_dev and bytes_per_dev/1e9:.2f}GB "
              f"flops/dev={roof.hlo_gflops:.1f}G coll={roof.coll_gbytes:.2f}GB")
    except Exception as e:  # noqa: BLE001 — report, don't abort the sweep
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 1))
        print(f"[dryrun] FAIL {arch} {shape_name} {mesh_name}: {rec['error']}")
    finally:
        shd.set_active_mesh(None)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--cells", default="all",
                    help="'all' | family (lm|gnn|recsys) | 'arch:shape[,arch:shape...]'")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cells = steps_mod.all_cells()
    if args.cells != "all":
        if args.cells in ("lm", "gnn", "recsys"):
            cells = [
                (a, s) for a, s in cells
                if cfgs.get_arch(a).family == args.cells
            ]
        else:
            want = [tuple(c.split(":")) for c in args.cells.split(",")]
            cells = [c for c in cells if c in want]

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", make_production_mesh(multi_pod=True)))

    os.makedirs("reports", exist_ok=True)
    records = []
    for mesh_name, mesh in meshes:
        for arch, shape_name in cells:
            records.append(run_cell(arch, shape_name, mesh, mesh_name))
            out = args.out or f"reports/dryrun_{args.mesh}.json"
            with open(out, "w") as f:  # checkpoint after every cell
                json.dump(records, f, indent=1)

    n_ok = sum(r["status"] == "ok" for r in records)
    print(f"[dryrun] {n_ok}/{len(records)} cells compiled")
    return 0 if n_ok == len(records) else 1


if __name__ == "__main__":
    raise SystemExit(main())
