"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — required for the 512-placeholder-device dry-run
to control initialization order.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, multi_pod: bool = False):
    """Small mesh for CI-scale sharding tests (requires >= n devices)."""
    shape = (2, n_data, n_model) if multi_pod else (n_data, n_model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """The batch-parallel axes of a mesh ("pod" included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
