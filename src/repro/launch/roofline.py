"""Roofline-term derivation from compiled dry-run artifacts.

    compute    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

HLO FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes
are parsed out of the compiled HLO text: operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute, scaled by a
per-op traffic factor (ring-algorithm bytes actually crossing links).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (assignment-specified)
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link (per-chip effective)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}]+)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}|replica_groups=\[\d+,(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    if m.group(2):  # iota form replica_groups=[G,S] -> group size S
        return int(m.group(2))
    first = m.group(1).split("}")[0].lstrip("{")
    ids = [x for x in first.split(",") if x.strip() != ""]
    return max(len(ids), 1)


_OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def collective_bytes(hlo_text: str, num_devices: int,
                     loop_trips: tuple = ()) -> Dict[str, float]:
    """Per-chip bytes crossing ICI links, by collective op type.

    Ring-algorithm factors for a group of size G over the *output/operand*
    size B (per-shard semantics follow the HLO result shapes):
      all-gather:        result is the gathered (full) buffer; each chip
                         receives (G-1)/G of it  -> B * (G-1)/G
      reduce-scatter:    same traffic as all-gather on the input side
      all-reduce:        2 * B * (G-1)/G (reduce-scatter + all-gather)
      all-to-all:        B * (G-1)/G leaves each chip
      collective-permute: B (point-to-point)

    XLA counts a while (jax.lax.scan) body ONCE in the HLO text, so
    collectives whose op_name metadata shows scan nesting are scaled by
    ``loop_trips``: a collective at while-depth k is multiplied by
    prod(loop_trips[:k]) (e.g. (n_layers, seq_chunks) for an LM step).
    """
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.match(line)
        if not m:
            continue
        result_shape, op = m.group(1), m.group(2)
        b = _shape_bytes(result_shape)
        g = _group_size(line, num_devices)
        if g <= 1:
            continue
        if loop_trips:
            opname = _OPNAME_RE.search(line)
            depth = opname.group(1).count("while/body") if opname else 0
            for trip in loop_trips[: min(depth, len(loop_trips))]:
                b *= trip
        frac = (g - 1) / g
        if op == "all-gather":
            out[op] += b * frac
        elif op == "reduce-scatter":
            out[op] += b * frac * g  # result is 1/G of the reduced buffer
        elif op == "all-reduce":
            out[op] += 2 * b * frac
        elif op == "all-to-all":
            out[op] += b * frac
        elif op == "collective-permute":
            out[op] += b
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    num_devices: int
    hlo_gflops: float            # per device
    hlo_gbytes: float            # per device
    coll_gbytes: float           # per device
    compute_s: float
    memory_s: float
    collective_s: float
    model_gflops: float          # analytic 6*N*D (global, per step)
    bytes_per_device: Optional[float] = None
    coll_breakdown: Optional[dict] = None

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flop_ratio(self) -> float:
        total = self.hlo_gflops * self.num_devices
        return self.model_gflops / total if total > 0 else 0.0

    @property
    def roofline_fraction(self) -> float:
        """fraction of the ideal compute roofline achieved if the step runs
        at its dominant-term time: (model_flops/chips/peak) / bound_s."""
        ideal = self.model_gflops * 1e9 / self.num_devices / PEAK_FLOPS
        return ideal / self.bound_s if self.bound_s > 0 else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "devices": self.num_devices,
            "hlo_gflops_per_dev": round(self.hlo_gflops, 3),
            "hlo_gbytes_per_dev": round(self.hlo_gbytes, 3),
            "coll_gbytes_per_dev": round(self.coll_gbytes, 3),
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "model_gflops": round(self.model_gflops, 1),
            "useful_flop_ratio": round(self.useful_flop_ratio, 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
            "bytes_per_device": self.bytes_per_device,
            "coll_breakdown": self.coll_breakdown,
        }


def analyze(arch, shape, mesh_name, num_devices, cost, hlo_text,
            model_flops: float, memory_bytes: Optional[float] = None,
            loop_trips: tuple = (),
            analytic: Optional[dict] = None) -> Roofline:
    """``analytic`` (flops_per_dev, hbm_bytes_per_dev) overrides the HLO
    cost_analysis numbers for scan-over-layers programs, where XLA counts
    the loop body once (methodology: EXPERIMENTS.md). The HLO-parsed
    collective bytes always come from the compiled text, with while-depth
    trip scaling."""
    per_dev_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    if analytic is not None:
        per_dev_flops = analytic["flops_per_dev"]
        raw_bytes = analytic["hbm_bytes_per_dev"]
    coll = collective_bytes(hlo_text, num_devices, loop_trips)
    coll_total = sum(coll.values())
    compute_s = per_dev_flops / PEAK_FLOPS
    memory_s = raw_bytes / HBM_BW
    collective_s = coll_total / LINK_BW
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, num_devices=num_devices,
        hlo_gflops=per_dev_flops / 1e9, hlo_gbytes=raw_bytes / 1e9,
        coll_gbytes=coll_total / 1e9,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_gflops=model_flops / 1e9,
        bytes_per_device=memory_bytes,
        coll_breakdown={k: round(v / 1e9, 3) for k, v in coll.items()},
    )


# ---------------------------------------------------------------------------
# Analytic per-device compute/memory terms for scan-over-layers LM programs
# ---------------------------------------------------------------------------
def analytic_lm_terms(cfg, shape, num_devices: int, n_model: int = 16,
                      n_batch_shards: Optional[int] = None) -> dict:
    """Napkin-math FLOPs and HBM bytes per device for one step.

    Conventions: params stored fp32, matmuls in bf16; remat recomputes the
    forward in the backward (trunk factor 8ND/6ND = 4/3); microbatching
    re-reads weights once per microbatch; loss CE is sequence-chunked (its
    logits traffic counted explicitly)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab
    H, hd, kv = cfg.n_heads, cfg.head_dim, cfg.n_kv
    if n_batch_shards is None:
        n_batch_shards = num_devices // n_model
    tokens = shape.global_batch * shape.seq_len
    tokens_dev = tokens / n_batch_shards
    S = shape.seq_len
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    p_local = n_total / num_devices  # FSDP: weights sharded over all chips
    mb = max(getattr(cfg, "microbatches", 1), 1)
    mb = max(min(mb, shape.global_batch // n_batch_shards), 1)

    # ---- FLOPs ----
    if shape.kind == "train":
        trunk = 8.0 * n_active * tokens          # 2 fwd + 4 bwd + 2 remat
        attn = 4.0 * 2.0 * shape.global_batch * S * S * H * hd * L / 2.0
        flops = (trunk + attn) / num_devices
        passes = 3.0 * mb                        # fwd + bwd + remat, per mb
    elif shape.kind == "prefill":
        trunk = 2.0 * n_active * tokens
        attn = 2.0 * shape.global_batch * S * S * H * hd * L  # qk+av, causal/2*2
        flops = (trunk + attn) / num_devices
        passes = 1.0
    else:  # decode
        trunk = 2.0 * n_active * shape.global_batch
        attn = 2.0 * 2.0 * shape.global_batch * S * kv * hd * L
        flops = (trunk + attn) / num_devices
        passes = 1.0

    # ---- HBM bytes ----
    w_read = p_local * 4.0 * passes              # weights re-read per pass
    if shape.kind == "train":
        opt = p_local * 4.0 * 4.0                # grad w + opt read/update
        act = 3.0 * 2.0 * tokens_dev * d * 2.0 * L / (
            n_model if getattr(cfg, "seq_shard", False) else 1.0
        )
        logits_traffic = 2.0 * tokens_dev * (V / n_model) * 4.0
        hbm = w_read + opt + act + logits_traffic
    elif shape.kind == "prefill":
        act = 2.0 * tokens_dev * d * 2.0 * L
        kv_write = 2.0 * tokens_dev * kv * hd * 2.0 * L
        hbm = w_read + act + kv_write
    else:  # decode: the whole (fully sharded) KV cache is read once per step
        kv_bytes = 2.0 * shape.global_batch * S * kv * hd * 2.0 * L / num_devices
        hbm = w_read + kv_bytes
    return {"flops_per_dev": flops, "hbm_bytes_per_dev": hbm}


def model_flops_for(arch_cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6*N*D for LM train (N=active params, D=tokens);
    2*N*D for inference; GNN/recsys analogues documented inline."""
    fam = arch_cfg.family
    if fam == "lm":
        n_active = arch_cfg.active_param_count()
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            return 6.0 * n_active * tokens
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            return 2.0 * n_active * tokens
        # decode: one token per sequence + attention over the KV cache
        attn = (
            2.0 * 2.0 * arch_cfg.n_layers * arch_cfg.n_kv * arch_cfg.head_dim
            * shape.seq_len * shape.global_batch
        )
        return 2.0 * n_active * shape.global_batch + attn
    if fam == "gnn":
        d = arch_cfg.d_hidden
        # message MLPs dominate: ~2 * E * (mats per layer) * d^2 per layer
        mats = {"gin": 2, "pna": 14, "egnn": 6, "nequip": 12}[arch_cfg.kind]
        if shape.kind == "minibatch":
            from repro.graph.sampler import subgraph_shape

            _, e = subgraph_shape(shape.batch_nodes, tuple(shape.fanout))
        elif shape.kind == "molecule":
            e = shape.batch_graphs * shape.n_edges
        else:
            e = shape.n_edges
        fwd = 2.0 * e * mats * d * d * arch_cfg.n_layers
        return 3.0 * fwd if shape.kind != "serve" else fwd
    if fam == "recsys":
        d = arch_cfg.embed_dim
        if shape.kind == "train":
            lookup = 2.0 * shape.batch * arch_cfg.hist_len * d
            routing = (
                2.0 * shape.batch * arch_cfg.hist_len * arch_cfg.n_interests
                * d * arch_cfg.capsule_iters * 2
            )
            neg = 2.0 * shape.batch * arch_cfg.n_negatives * d
            return 3.0 * (lookup + routing + neg)
        if shape.kind == "serve":
            return 2.0 * shape.batch * (
                arch_cfg.hist_len * d
                + arch_cfg.n_interests * 64 * d
            )
        return 2.0 * shape.n_candidates * arch_cfg.n_interests * d
    raise ValueError(fam)
