"""Batched serving driver: prefill + decode loop with continuous batching.

    PYTHONPATH=src python -m repro.launch.serve --arch starcoder2-7b --smoke \
        --requests 16 --prefill 64 --decode 32

Serves the reduced config on CPU; the full configs' serving steps are the
decode/prefill dry-run cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import base as cfgs
from repro.data.pipeline import zipf_ids
from repro.nn import transformer as tfm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prefill", type=int, default=64)
    ap.add_argument("--decode", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = cfgs.get_arch(args.arch)
    if args.smoke:
        cfg = cfgs.reduced(cfg)
    rng = np.random.default_rng(0)
    max_len = args.prefill + args.decode

    params = tfm.init(jax.random.PRNGKey(0), cfg)
    prefill = jax.jit(lambda p, t: tfm.prefill(p, cfg, t, max_len=max_len))
    decode = jax.jit(lambda p, c, t: tfm.decode_step(p, cfg, c, t))

    done, t0 = 0, time.time()
    lat = []
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        tokens = zipf_ids(rng, (args.batch, args.prefill), cfg.vocab)
        t1 = time.time()
        logits, cache = prefill(params, jnp.asarray(tokens))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(args.decode - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(out[-1])
        lat.append(time.time() - t1)
        done += n
    dt = time.time() - t0
    toks = args.requests * args.decode
    print(f"[serve] {args.requests} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s); batch latency p50="
          f"{np.percentile(lat, 50)*1e3:.0f}ms p99={np.percentile(lat, 99)*1e3:.0f}ms")


if __name__ == "__main__":
    main()
