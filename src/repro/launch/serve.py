"""Serving CLI — a thin front-end over ``repro.serve.engine``.

    # transformer prefill+decode loop (the original driver, partial
    # batches fixed):
    PYTHONPATH=src python -m repro.launch.serve --engine lm \
        --arch starcoder2-7b --requests 16 --prefill 64 --decode 32

    # MIND candidate scoring through the GRASP embedding cache on a
    # zipf-skewed stream with deadlines + shed load:
    PYTHONPATH=src python -m repro.launch.serve --engine recsys \
        --requests 256 --qps 2000 --budget-kb 256 --json /tmp/serve.json

    # put either engine behind the repro.gateway RPC front-end (serves
    # until Ctrl-C, then drains gracefully):
    PYTHONPATH=src python -m repro.launch.serve --engine recsys \
        --gateway 127.0.0.1:8077
    curl -s -XPOST localhost:8077/v1/score \
        -d '{"hist": [1,2,3], "candidates": [4,5]}'

All real logic lives in ``repro.serve``/``repro.gateway``; this module
only parses flags and prints/emits the metrics snapshot.
"""
from __future__ import annotations

import argparse
import json


def _run_gateway(args):
    """Build the requested engine, wrap it in a pump, and serve until
    interrupted; Ctrl-C triggers the graceful drain protocol."""
    from repro.gateway import EnginePump, GatewayServer
    from repro.serve.scheduler import SchedulerConfig

    host, _, port = args.gateway.rpartition(":")
    # best-effort unless a deadline was asked for explicitly — a blanket
    # 50ms default would shed every LM batch before it finished decoding
    deadline_s = None if args.deadline_ms is None else args.deadline_ms / 1e3
    sched = SchedulerConfig(max_batch=args.batch, max_queue=args.max_queue,
                            default_deadline_s=deadline_s)
    if args.engine == "lm":
        from repro.serve.engine import LMServeEngine

        engine = LMServeEngine(arch=args.arch, smoke=args.smoke,
                               sched_config=sched, prefill=args.prefill,
                               decode=args.decode)
        engine.warmup()
        name = "generate"
    else:
        import jax

        from repro.configs import base as cfgs
        from repro.nn import recsys as recsys_mod
        from repro.serve.cache import CacheConfig
        from repro.serve.engine import RecsysServeEngine

        cfg = cfgs.get_arch("mind")
        if args.smoke:
            cfg = cfgs.reduced(cfg)
        engine = RecsysServeEngine(
            recsys_mod.init(jax.random.PRNGKey(0), cfg), cfg,
            CacheConfig(budget_bytes=args.budget_kb << 10,
                        hot_fraction=args.hot_frac, policy=args.policy),
            sched)
        engine.warmup(candidates=args.candidates)
        name = "score"

    server = GatewayServer({name: EnginePump(engine, name)},
                           host=host or "127.0.0.1", port=int(port),
                           supervise=not args.no_supervise,
                           snapshot_dir=args.snapshot_dir).start()
    warm = ""
    if args.snapshot_dir and getattr(engine, "cache", None) is not None:
        warm = (" (warm cache restore)" if engine.metrics.counters.get(
            "snapshot_restores") else " (cold start)")
    print(f"[gateway] {args.engine} engine on {server.url} "
          f"(/v1/{name}, /healthz, /metrics){warm} — Ctrl-C to drain and stop")
    try:
        while True:
            server._thread.join(3600.0)
    except KeyboardInterrupt:
        print("[gateway] draining...")
        server.stop()
        snap = engine.metrics.snapshot()
        c = snap["counters"]
        print(f"[gateway] stopped: completed={c.get('completed', 0)} "
              f"shed={c.get('shed', 0)} rejected={c.get('rejected', 0)}")
        return snap


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--engine", choices=("lm", "recsys"), default="lm")
    ap.add_argument("--gateway", default=None, metavar="HOST:PORT",
                    help="serve over the repro.gateway RPC front-end "
                         "instead of running a local loop")
    ap.add_argument("--snapshot-dir", default=None, metavar="DIR",
                    help="gateway mode: save the GRASP cache state here on "
                         "drain and warm-restore it on startup")
    ap.add_argument("--no-supervise", action="store_true",
                    help="gateway mode: disable the pump supervisor "
                         "(dead pump threads then stay dead)")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    # lm flags
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--prefill", type=int, default=64)
    ap.add_argument("--decode", type=int, default=32)
    # recsys flags
    ap.add_argument("--qps", type=float, default=2000.0)
    ap.add_argument("--budget-kb", type=int, default=256,
                    help="device cache budget for the embedding cache")
    ap.add_argument("--hot-frac", type=float, default=0.5,
                    help="share of the budget pinned (0 = unpinned baseline)")
    ap.add_argument("--policy", choices=("rrpv", "lru"), default="rrpv")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="queue deadline; local recsys loop defaults to "
                         "50ms, gateway mode to best-effort")
    ap.add_argument("--max-queue", type=int, default=256)
    ap.add_argument("--candidates", type=int, default=32)
    ap.add_argument("--zipf-a", type=float, default=1.1)
    ap.add_argument("--json", default=None, help="write metrics snapshot here")
    args = ap.parse_args(argv)

    if args.gateway:
        return _run_gateway(args)

    if args.engine == "lm":
        from repro.serve.engine import lm_loop

        return lm_loop(arch=args.arch, smoke=args.smoke,
                       requests=args.requests, batch=args.batch,
                       prefill=args.prefill, decode=args.decode)

    from repro.configs import base as cfgs
    from repro.serve.cache import CacheConfig
    from repro.serve.engine import StreamConfig, run_recsys_stream
    from repro.serve.scheduler import SchedulerConfig

    cfg = cfgs.get_arch("mind")
    if args.smoke:
        cfg = cfgs.reduced(cfg)
    deadline_ms = 50.0 if args.deadline_ms is None else args.deadline_ms
    snap = run_recsys_stream(
        cfg,
        CacheConfig(budget_bytes=args.budget_kb << 10,
                    hot_fraction=args.hot_frac, policy=args.policy),
        SchedulerConfig(max_batch=args.batch, max_queue=args.max_queue,
                        default_deadline_s=deadline_ms / 1e3),
        StreamConfig(requests=args.requests, qps=args.qps,
                     candidates=args.candidates, zipf_a=args.zipf_a,
                     deadline_s=deadline_ms / 1e3),
    )
    c, lat = snap["counters"], snap["latency"]
    e2e = lat.get("e2e", {})
    print(f"[serve:recsys] {c.get('completed', 0)}/{snap['config']['requests']}"
          f" served, shed={c.get('shed', 0)} rejected={c.get('rejected', 0)}; "
          f"cache hit={snap['hit_rate']:.1%} "
          f"(hot={c.get('hot_hits', 0)} cold={c.get('cold_hits', 0)} "
          f"miss={c.get('misses', 0)}); "
          f"e2e p50={e2e.get('p50_s', 0)*1e3:.1f}ms "
          f"p99={e2e.get('p99_s', 0)*1e3:.1f}ms")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
    return snap


if __name__ == "__main__":
    main()
