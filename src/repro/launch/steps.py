"""Step builders + abstract input specs for every (arch x shape) cell.

``build_cell(arch_name, shape_name, mesh)`` returns a :class:`Cell` with the
jitted-able step function, abstract arguments (ShapeDtypeStructs — no
allocation) and in/out shardings: everything dryrun/train/serve need.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import base as cfgs
from repro.dist import sharding as shd
from repro.nn import gnn as gnn_mod
from repro.nn import recsys as recsys_mod
from repro.nn import transformer as tfm
from repro.train import optimizer as opt_mod

F32, BF16, I32, BOOL = jnp.float32, jnp.bfloat16, jnp.int32, jnp.bool_


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    step_fn: Callable
    args: Tuple[Any, ...]          # abstract (ShapeDtypeStruct) pytrees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    notes: str = ""
    donate: Tuple[int, ...] = ()


def _named(mesh, spec_tree, value_tree):
    """PartitionSpec pytree -> NamedSharding pytree matching value tree."""
    def to_ns(spec):
        return shd.ns(mesh, *spec)

    specs = jax.tree_util.tree_map(
        to_ns, spec_tree, is_leaf=lambda x: isinstance(x, P)
    )
    # broadcast spec tree onto value tree (layers dict shared across L)
    flat_v, tree_v = jax.tree_util.tree_flatten(value_tree)
    flat_s = tree_v.flatten_up_to(_broadcast_like(specs, value_tree))
    return jax.tree_util.tree_unflatten(tree_v, flat_s)


def _broadcast_like(spec_tree, value_tree):
    """specs may be shallower than values (e.g. one P for a whole subtree)."""
    if isinstance(spec_tree, NamedSharding):
        return jax.tree_util.tree_map(lambda _: spec_tree, value_tree)
    if isinstance(spec_tree, dict):
        return {
            k: _broadcast_like(spec_tree[k], value_tree[k]) for k in value_tree
        }
    if isinstance(spec_tree, (list, tuple)):
        return type(spec_tree)(
            _broadcast_like(s, v) for s, v in zip(spec_tree, value_tree)
        )
    return spec_tree


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------
def _lm_abstract_params(cfg, dtype=None):
    p = jax.eval_shape(partial(tfm.init, cfg=cfg), sds((2,), jnp.uint32))
    if dtype is not None:  # serving checkpoints are bf16
        p = jax.tree_util.tree_map(
            lambda s: sds(s.shape, dtype) if s.dtype == jnp.float32 else s, p
        )
    return p


def _serving_fsdp(cfg, mesh) -> bool:
    """Serving wants TP-only weights (no per-layer data-axis re-gather) —
    unless the bf16 weights don't fit a chip's HBM at TP-only sharding
    (nemotron-340b: 42.6GB/chip > 16GB -> keep 2D sharding)."""
    tp_bytes = cfg.param_count() * 2 / mesh.shape["model"]
    return tp_bytes > 8e9


def _lm_train_cell(cfg, shape, mesh) -> Cell:
    opt_cfg = opt_mod.for_arch(cfg)
    opt_init, opt_update = opt_mod.make(opt_cfg)
    # each microbatch must still cover every batch shard
    batch_shards = 1
    for a in shd.batch_axes(mesh):
        batch_shards *= mesh.shape[a]
    mb = max(min(cfg.microbatches, shape.global_batch // batch_shards), 1)
    assert shape.global_batch % mb == 0
    baxes = shd.batch_axes(mesh)

    def train_step(params, opt_state, batch):
        if mb == 1:
            loss, grads = jax.value_and_grad(tfm.loss_fn)(params, cfg, batch)
        else:
            # gradient accumulation: peak activation stash = one microbatch
            split = jax.tree_util.tree_map(
                lambda x: shd.constrain(
                    x.reshape((mb, x.shape[0] // mb) + x.shape[1:]),
                    None, baxes, *(None,) * (x.ndim - 1),
                ),
                batch,
            )

            def acc_fn(carry, mbatch):
                g_acc, l_acc = carry
                l, g = jax.value_and_grad(tfm.loss_fn)(params, cfg, mbatch)
                return (
                    jax.tree_util.tree_map(jnp.add, g_acc, g),
                    l_acc + l,
                ), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), _ = jax.lax.scan(acc_fn, (zeros, 0.0), split)
            grads = jax.tree_util.tree_map(lambda g: g / mb, gsum)
            loss = lsum / mb
        new_params, new_state = opt_update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss}

    a_params = _lm_abstract_params(cfg)
    a_opt = jax.eval_shape(opt_init, a_params)
    a_batch = {
        "tokens": sds((shape.global_batch, shape.seq_len), I32),
        "labels": sds((shape.global_batch, shape.seq_len), I32),
    }
    pspec = shd.lm_param_spec(cfg)
    p_shard = _named(mesh, pspec, a_params)
    o_shard = _named(mesh, shd.opt_state_spec(pspec, opt_cfg.name), a_opt)
    b_shard = _named(mesh, shd.lm_batch_spec(mesh), a_batch)
    scalar = shd.ns(mesh)
    return Cell(
        arch=cfg.name, shape=shape.name, step_fn=train_step,
        args=(a_params, a_opt, a_batch),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, {"loss": scalar}),
        donate=(0, 1),
    )


def _lm_prefill_cell(cfg, shape, mesh) -> Cell:
    # (perf log: seq-sharding prefill activations was tried and REFUTED —
    # it 10x'd collective bytes; head-TP with repeated-KV einsums wins for
    # prefill. See EXPERIMENTS.md §Perf.)
    def prefill_step(params, tokens):
        return tfm.prefill(params, cfg, tokens)

    a_params = _lm_abstract_params(cfg, dtype=BF16)
    a_tokens = sds((shape.global_batch, shape.seq_len), I32)
    # serving: no optimizer state -> weights fit TP-only; FSDP sharding
    # would re-gather weights over data every layer (perf iteration log,
    # EXPERIMENTS.md §Perf-serving)
    pspec = shd.lm_param_spec(cfg, fsdp=_serving_fsdp(cfg, mesh))
    b = shd.batch_axes(mesh)
    p_shard = _named(mesh, pspec, a_params)
    # output cache: batch over data axes, sequence over model (serving
    # layout; nemotron-class caches exceed HBM on batch sharding alone)
    cache_shard = tfm.KVCache(
        k=shd.ns(mesh, None, b, "model", None, None),
        v=shd.ns(mesh, None, b, "model", None, None),
        length=shd.ns(mesh),
    )
    return Cell(
        arch=cfg.name, shape=shape.name, step_fn=prefill_step,
        args=(a_params, a_tokens),
        in_shardings=(p_shard, shd.ns(mesh, b, None)),
        out_shardings=(shd.ns(mesh, b, None), cache_shard),
    )


def _lm_decode_cell(cfg, shape, mesh) -> Cell:
    """decode_32k: KV cache sharded on batch. long_500k (batch=1): KV cache
    sharded on *sequence* across (data, model) — FlashDecoding-style; the
    partial-softmax combine lowers to the psum GSPMD inserts for the
    softmax/attention reductions over the sharded axis."""
    long_context = shape.global_batch == 1

    def decode_step(params, cache, token):
        return tfm.decode_step(params, cfg, cache, token)

    a_params = _lm_abstract_params(cfg, dtype=BF16)
    a_cache = tfm.KVCache(
        k=sds((cfg.n_layers, shape.global_batch, shape.seq_len, cfg.n_kv,
               cfg.head_dim), BF16),
        v=sds((cfg.n_layers, shape.global_batch, shape.seq_len, cfg.n_kv,
               cfg.head_dim), BF16),
        length=sds((), I32),
    )
    a_token = sds((shape.global_batch,), I32)
    pspec = shd.lm_param_spec(cfg, fsdp=_serving_fsdp(cfg, mesh))
    p_shard = _named(mesh, pspec, a_params)
    b = shd.batch_axes(mesh)
    if long_context:
        seq_axes = tuple(mesh.axis_names)  # all axes onto the KV sequence
        kv_spec = shd.ns(mesh, None, None, seq_axes, None, None)
        tok_spec = shd.ns(mesh)
    else:
        # batch over data axes + KV sequence over model (flash-decoding)
        kv_spec = shd.ns(mesh, None, b, "model", None, None)
        tok_spec = shd.ns(mesh, b)
    cache_shard = tfm.KVCache(k=kv_spec, v=kv_spec, length=shd.ns(mesh))
    return Cell(
        arch=cfg.name, shape=shape.name, step_fn=decode_step,
        args=(a_params, a_cache, a_token),
        in_shardings=(p_shard, cache_shard, tok_spec),
        out_shardings=(
            shd.ns(mesh, b if not long_context else None, None),
            cache_shard,
        ),
        notes="flash-decoding seq-sharded KV" if long_context else "",
        donate=(1,),
    )


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------
N_CLASSES = 47  # ogbn-products label count


def _gnn_loss(params, cfg, batch):
    if cfg.kind in ("gin", "pna"):
        logits = gnn_mod.apply(params, cfg, batch)
        labels = batch["labels"]
        if "seeds" in batch:  # minibatch: loss on seed nodes only
            logits = jnp.take(logits, batch["seeds"], axis=0)
        elif "graph_id" in batch:  # molecule: graph classification readout
            n_graphs = labels.shape[0]
            logits = jax.ops.segment_sum(logits, batch["graph_id"], n_graphs)
        logp = jax.nn.log_softmax(logits.astype(F32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        return -ll.mean()
    if cfg.kind == "egnn":
        h, coords = gnn_mod.apply(params, cfg, batch)
        energy = h.sum(axis=-1)
        return _energy_loss(energy, batch)
    if cfg.kind == "nequip":
        energy = gnn_mod.apply(params, cfg, batch)
        return _energy_loss(energy, batch)
    raise ValueError(cfg.kind)


def _energy_loss(energy, batch):
    if "graph_id" in batch:  # molecule: per-graph energy regression
        n_graphs = batch["labels"].shape[0]
        e_graph = jax.ops.segment_sum(energy, batch["graph_id"], n_graphs)
        return jnp.mean((e_graph - batch["labels"]) ** 2)
    return jnp.mean(energy**2) * 1e-3  # full-graph: bounded synthetic target


def _pad_to(n: int, mult: int = 512) -> int:
    """Shardability padding: edge/candidate streams are padded to a multiple
    of the largest mesh size (512); emask/sentinel entries absorb the pad."""
    return (n + mult - 1) // mult * mult


def _gnn_batch_abstract(cfg, shape) -> dict:
    if shape.kind == "full_graph":
        n, e = shape.n_nodes, _pad_to(shape.n_edges)
        b = {
            "x": sds((n, shape.d_feat), F32),
            "src": sds((e,), I32),
            "dst": sds((e,), I32),
            "emask": sds((e,), BOOL),
            "labels": sds((n,), I32),
            "coords": sds((n, 3), F32),
            "species": sds((n,), I32),
        }
    elif shape.kind == "minibatch":
        from repro.graph.sampler import subgraph_shape

        n_sub, e_sub = subgraph_shape(shape.batch_nodes, tuple(shape.fanout))
        b = {
            "x": sds((n_sub, shape.d_feat), F32),
            "src": sds((e_sub,), I32),
            "dst": sds((e_sub,), I32),
            "emask": sds((e_sub,), BOOL),
            "labels": sds((shape.batch_nodes,), I32),
            "seeds": sds((shape.batch_nodes,), I32),
            "coords": sds((n_sub, 3), F32),
            "species": sds((n_sub,), I32),
        }
    elif shape.kind == "molecule":
        nn_ = shape.batch_graphs * shape.n_nodes
        ee = shape.batch_graphs * shape.n_edges
        b = {
            "x": sds((nn_, shape.d_feat), F32),
            "src": sds((ee,), I32),
            "dst": sds((ee,), I32),
            "emask": sds((ee,), BOOL),
            "coords": sds((nn_, 3), F32),
            "species": sds((nn_,), I32),
            "graph_id": sds((nn_,), I32),
            # gin/pna: graph classification (int); egnn/nequip: energy (f32)
            "labels": sds(
                (shape.batch_graphs,),
                I32 if cfg.kind in ("gin", "pna") else F32,
            ),
        }
    else:
        raise ValueError(shape.kind)
    return b


def _gnn_grasp_cell(cfg, shape, mesh) -> Cell:
    """GRASP-sharded full-graph GIN (dist/collectives.py): hot prefix
    replicated, cold partitioned, bounded halo all-gather per layer —
    the paper's technique as the distributed exchange (hillclimb cell)."""
    from repro.dist import collectives as coll

    opt_cfg = opt_mod.OptConfig(name="adamw", lr=1e-3)
    opt_init, opt_update = opt_mod.make(opt_cfg)
    spec = coll.partition_spec_for(
        shape.n_nodes, shape.n_edges, mesh.size,
        hot_budget_bytes=coll.HOT_REPLICA_BUDGET_BYTES,
        elem_bytes=shape.d_feat * 4,
    )
    step, batch_specs = coll.make_grasp_gin_step(
        spec, cfg, shape.d_feat, N_CLASSES, mesh, opt_update
    )
    a_params = jax.eval_shape(
        partial(gnn_mod.init, cfg=cfg, d_feat=shape.d_feat),
        sds((2,), jnp.uint32),
    )
    a_opt = jax.eval_shape(opt_init, a_params)
    p_dev = spec.num_devices
    a_batch = {
        "x_hot": sds((spec.hot, shape.d_feat), F32),
        "x_cold": sds((p_dev, spec.cold_per_dev, shape.d_feat), F32),
        "esrc": sds((p_dev, spec.e_loc), I32),
        "edst": sds((p_dev, spec.e_loc), I32),
        "emask": sds((p_dev, spec.e_loc), BOOL),
        "pub": sds((p_dev, spec.c_pub), I32),
        "labels": sds((p_dev, spec.n_own), I32),
    }
    p_shard = jax.tree_util.tree_map(lambda _: shd.ns(mesh), a_params)
    o_shard = jax.tree_util.tree_map(lambda _: shd.ns(mesh), a_opt)
    b_shard = {k: shd.ns(mesh, *batch_specs[k]) for k in a_batch}
    return Cell(
        arch=cfg.name, shape=shape.name, step_fn=step,
        args=(a_params, a_opt, a_batch),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, {"loss": shd.ns(mesh)}),
        donate=(0, 1),
        notes=f"grasp exchange hot={spec.hot} c_pub={spec.c_pub}",
    )


def _gnn_train_cell(cfg, shape, mesh) -> Cell:
    if cfg.kind == "gin" and cfg.grasp and shape.name == "ogb_products":
        return _gnn_grasp_cell(cfg, shape, mesh)
    opt_cfg = opt_mod.OptConfig(name="adamw", lr=1e-3)
    opt_init, opt_update = opt_mod.make(opt_cfg)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(_gnn_loss)(params, cfg, batch)
        new_params, new_state = opt_update(grads, opt_state, params)
        return new_params, new_state, {"loss": loss}

    d_feat = shape.d_feat
    a_params = jax.eval_shape(
        partial(gnn_mod.init, cfg=cfg, d_feat=d_feat), sds((2,), jnp.uint32)
    )
    a_batch = _gnn_batch_abstract(cfg, shape)
    a_opt = jax.eval_shape(opt_init, a_params)

    p_shard = jax.tree_util.tree_map(lambda _: shd.ns(mesh), a_params)
    o_shard = jax.tree_util.tree_map(lambda _: shd.ns(mesh), a_opt)
    bspec = shd.gnn_batch_spec(mesh, shape.kind)
    b_shard = {k: shd.ns(mesh, *bspec[k]) if k in bspec else shd.ns(mesh)
               for k in a_batch}
    return Cell(
        arch=cfg.name, shape=shape.name, step_fn=train_step,
        args=(a_params, a_opt, a_batch),
        in_shardings=(p_shard, o_shard, b_shard),
        out_shardings=(p_shard, o_shard, {"loss": shd.ns(mesh)}),
        donate=(0, 1),
    )


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------
def grasp_hot_rows(cfg, mesh) -> int:
    """GRASP plan for the item table: hot prefix sized by the per-chip
    fast-memory budget (replication cost) and shardability of the tail."""
    if not cfg.grasp:
        return 0
    from repro.core import plan as plan_mod

    budget_rows = plan_mod.entries_for_budget(
        64 << 20, cfg.embed_dim * 4  # 64MB replica budget
    )
    hot = 1 << (budget_rows.bit_length() - 1)
    # cold remainder must shard over 512 chips
    while hot > 0 and (cfg.n_items - hot) % 512 != 0:
        hot //= 2
    return hot


def _recsys_cell(cfg, shape, mesh) -> Cell:
    opt_cfg = opt_mod.OptConfig(name="adamw", lr=1e-3)
    opt_init, opt_update = opt_mod.make(opt_cfg)

    # Perf log (§Perf-mind): hot/cold table replication wins ONLY for
    # retrieval-style scoring (-47% collective); for dense-batch train /
    # serve lookups GSPMD's output-psum gather is already optimal and the
    # compacted cold path regresses (refuted) — so the GRASP layout is
    # applied to the retrieval cell only.
    hot_rows = grasp_hot_rows(cfg, mesh) if shape.kind == "retrieval" else 0
    a_params = jax.eval_shape(
        partial(recsys_mod.init, cfg=cfg, hot_rows=hot_rows),
        sds((2,), jnp.uint32),
    )
    pspec = shd.recsys_param_spec(cfg, grasp=hot_rows > 0)
    p_shard = _named(mesh, pspec, a_params)
    b = shd.batch_axes(mesh)
    hl = cfg.hist_len

    if shape.kind == "train":
        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(recsys_mod.loss_fn)(
                params, cfg, batch
            )
            new_params, new_state = opt_update(grads, opt_state, params)
            return new_params, new_state, {"loss": loss}

        a_opt = jax.eval_shape(opt_init, a_params)
        o_shard = _named(mesh, shd.opt_state_spec(pspec, "adamw"), a_opt)
        a_batch = {
            "hist": sds((shape.batch, hl), I32),
            "hist_mask": sds((shape.batch, hl), BOOL),
            "target": sds((shape.batch,), I32),
            "negatives": sds((cfg.n_negatives,), I32),
        }
        b_shard = _named(mesh, shd.recsys_batch_spec(mesh, "train"), a_batch)
        return Cell(cfg.name, shape.name, step, (a_params, a_opt, a_batch),
                    (p_shard, o_shard, b_shard),
                    (p_shard, o_shard, {"loss": shd.ns(mesh)}),
                    donate=(0, 1))

    if shape.kind == "serve":
        def step(params, batch):
            return recsys_mod.serve_scores(params, cfg, batch)

        a_batch = {
            "hist": sds((shape.batch, hl), I32),
            "hist_mask": sds((shape.batch, hl), BOOL),
            "candidates": sds((shape.batch, 64), I32),
        }
        b_shard = _named(mesh, shd.recsys_batch_spec(mesh, "serve"), a_batch)
        return Cell(cfg.name, shape.name, step, (a_params, a_batch),
                    (p_shard, b_shard), shd.ns(mesh, b, None))

    if shape.kind == "retrieval":
        def step(params, batch):
            return recsys_mod.retrieval_scores(params, cfg, batch)

        a_batch = {
            "hist": sds((1, hl), I32),
            "hist_mask": sds((1, hl), BOOL),
            "candidates": sds((_pad_to(shape.n_candidates),), I32),
        }
        b_shard = _named(mesh, shd.recsys_batch_spec(mesh, "retrieval"), a_batch)
        return Cell(cfg.name, shape.name, step, (a_params, a_batch),
                    (p_shard, b_shard),
                    shd.ns(mesh, None, tuple(mesh.axis_names)))
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------
def build_cell(arch_name: str, shape_name: str, mesh) -> Cell:
    cfg = cfgs.get_arch(arch_name)
    shape = cfgs.SHAPES[cfg.family][shape_name]
    if cfg.family == "lm":
        if shape.kind == "train":
            return _lm_train_cell(cfg, shape, mesh)
        if shape.kind == "prefill":
            return _lm_prefill_cell(cfg, shape, mesh)
        if shape.kind == "decode":
            return _lm_decode_cell(cfg, shape, mesh)
    if cfg.family == "gnn":
        return _gnn_train_cell(cfg, shape, mesh)
    if cfg.family == "recsys":
        return _recsys_cell(cfg, shape, mesh)
    raise ValueError((arch_name, shape_name))


def all_cells() -> list[tuple[str, str]]:
    out = []
    for name, cfg in cfgs.all_archs().items():
        for shape_name in cfgs.SHAPES[cfg.family]:
            out.append((name, shape_name))
    return out
