"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b --smoke \
        --steps 50 --ckpt /tmp/ckpt

``--smoke`` swaps in the reduced config (CPU-sized); without it the full
config is used (requires the production mesh / real accelerators — on this
container use dryrun.py for full-size validation).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import base as cfgs
from repro.data import pipeline
from repro.nn import transformer as tfm
from repro.train import ft as ft_mod
from repro.train import optimizer as opt_mod
from repro.train.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (FT demo)")
    args = ap.parse_args(argv)

    cfg = cfgs.get_arch(args.arch)
    if cfg.family != "lm":
        raise SystemExit("train.py drives LM archs; see examples/ for others")
    if args.smoke:
        cfg = cfgs.reduced(cfg)
    shape = cfgs.LMShape("cli", "train", args.seq, args.batch)

    def loss(params, batch):
        return tfm.loss_fn(params, cfg, batch)

    def init_params():
        return tfm.init(jax.random.PRNGKey(0), cfg)

    trainer = Trainer(
        loss_fn=loss,
        init_params=init_params,
        opt_cfg=opt_mod.OptConfig(name="adamw", lr=args.lr),
        tcfg=TrainerConfig(
            num_steps=args.steps,
            ckpt_dir=args.ckpt,
            ckpt_every=max(args.steps // 5, 1),
            log_every=max(args.steps // 20, 1),
        ),
    )
    batch_fn = pipeline.make_batch_fn("lm", cfg, shape, seed=0)
    injector = ft_mod.FailureInjector(fail_at=tuple(args.fail_at))
    state = trainer.fit(batch_fn, injector=injector if args.fail_at else None)
    losses = [h["loss"] for h in trainer.history]
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    if trainer.watchdog.events:
        print(f"[train] straggler events: {trainer.watchdog.events}")
    return state


if __name__ == "__main__":
    main()
