"""GNN architectures: GIN, PNA, EGNN, NequIP-lite.

All message passing is expressed as gather (``jnp.take`` over edge endpoint
indices) + ``jax.ops.segment_sum``-family reductions — JAX has no CSR/CSC
sparse, so the edge-index scatter IS the system (assignment note). This is
exactly the Property-Array gather the paper targets: with DBG reordering the
hot (high-degree) node rows form a prefix, serviced by the ``hot_gather``
Pallas kernel / the hot-replicated distributed exchange.

Graph batch dict convention:
  x      (N, F) float32 node features
  src    (E,)  int32 edge sources
  dst    (E,)  int32 edge destinations
  emask  (E,)  bool   valid-edge mask (padding)
  coords (N, 3) float32 (egnn / nequip)
  species(N,)  int32   (nequip)
  graph_id (N,) int32  molecule batching (segment readout)
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig
from repro.nn import layers as L


def _mlp_init(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return [L.dense_init(k, a, b) for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp(params, x, act=jax.nn.silu, compute_dtype=jnp.float32):
    for i, p in enumerate(params):
        x = L.dense(p, x, compute_dtype)
        if i < len(params) - 1:
            x = act(x)
    return x


def _deg(dst, n, emask):
    ones = jnp.where(emask, 1.0, 0.0)
    return jax.ops.segment_sum(ones, dst, num_segments=n)


# ---------------------------------------------------------------------------
# GIN (Xu et al. 2019) — sum aggregator, learnable eps
# ---------------------------------------------------------------------------
def gin_init(key, cfg: GNNConfig, d_feat: int):
    ks = jax.random.split(key, cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        din = d_feat if i == 0 else d
        layers.append(
            {
                "mlp": _mlp_init(ks[i], [din, d, d]),
                "eps": jnp.zeros(()) if cfg.eps_learnable else None,
                "ln": L.layernorm_init(d),
            }
        )
    return {"layers": layers, "out": L.dense_init(ks[-1], d, cfg.d_out)}


def gin_apply(params, cfg: GNNConfig, batch: Dict):
    h, src, dst, emask = batch["x"], batch["src"], batch["dst"], batch["emask"]
    n = h.shape[0]
    for lp in params["layers"]:
        msg = jnp.take(h, src, axis=0)
        msg = jnp.where(emask[:, None], msg, 0.0)
        agg = jax.ops.segment_sum(msg, dst, num_segments=n)
        eps = lp["eps"] if lp["eps"] is not None else 0.0
        h = _mlp(lp["mlp"], (1.0 + eps) * h + agg)
        h = jax.nn.relu(L.layernorm(lp["ln"], h))
    return L.dense(params["out"], h, jnp.float32)


# ---------------------------------------------------------------------------
# PNA (Corso et al. 2020) — multi-aggregator + degree scalers
# ---------------------------------------------------------------------------
def pna_init(key, cfg: GNNConfig, d_feat: int):
    ks = jax.random.split(key, 2 * cfg.n_layers + 2)
    d = cfg.d_hidden
    n_agg = len(cfg.aggregators) * len(cfg.scalers)
    layers = []
    for i in range(cfg.n_layers):
        din = d_feat if i == 0 else d
        layers.append(
            {
                "pre": _mlp_init(ks[2 * i], [2 * din, d]),
                "post": _mlp_init(ks[2 * i + 1], [n_agg * d + din, d, d]),
                "ln": L.layernorm_init(d),
            }
        )
    return {"layers": layers, "out": L.dense_init(ks[-1], d, cfg.d_out)}


def pna_apply(params, cfg: GNNConfig, batch: Dict, mean_log_deg: float = 1.0):
    h, src, dst, emask = batch["x"], batch["src"], batch["dst"], batch["emask"]
    n = h.shape[0]
    deg = _deg(dst, n, emask)
    log_deg = jnp.log1p(deg)
    delta = max(mean_log_deg, 1e-3)

    for lp in params["layers"]:
        hi = jnp.take(h, dst, axis=0)
        hj = jnp.take(h, src, axis=0)
        m = _mlp(lp["pre"], jnp.concatenate([hi, hj], axis=-1))
        m = jnp.where(emask[:, None], m, 0.0)

        s = jax.ops.segment_sum(m, dst, num_segments=n)
        cnt = jnp.maximum(deg, 1.0)[:, None]
        mean = s / cnt
        mx = jax.ops.segment_max(jnp.where(emask[:, None], m, -jnp.inf), dst, num_segments=n)
        mx = jnp.where(jnp.isfinite(mx), mx, 0.0)
        mn = jax.ops.segment_min(jnp.where(emask[:, None], m, jnp.inf), dst, num_segments=n)
        mn = jnp.where(jnp.isfinite(mn), mn, 0.0)
        sq = jax.ops.segment_sum(m * m, dst, num_segments=n) / cnt
        # eps inside sqrt: grad(sqrt) at 0 is inf -> NaN gradients otherwise
        std = jnp.sqrt(jnp.maximum(sq - mean * mean, 0.0) + 1e-5)

        aggs = {"mean": mean, "max": mx, "min": mn, "std": std}
        scaled = []
        for a in cfg.aggregators:
            base = aggs[a]
            for sc in cfg.scalers:
                if sc == "identity":
                    scaled.append(base)
                elif sc == "amplification":
                    scaled.append(base * (log_deg / delta)[:, None])
                elif sc == "attenuation":
                    scaled.append(base * (delta / jnp.maximum(log_deg, 1e-3))[:, None])
        z = jnp.concatenate(scaled + [h], axis=-1)
        h = jax.nn.relu(L.layernorm(lp["ln"], _mlp(lp["post"], z)))
    return L.dense(params["out"], h, jnp.float32)


# ---------------------------------------------------------------------------
# EGNN (Satorras et al. 2021) — E(n)-equivariant, scalar-distance messages
# ---------------------------------------------------------------------------
def egnn_init(key, cfg: GNNConfig, d_feat: int):
    ks = jax.random.split(key, 3 * cfg.n_layers + 2)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        din = d_feat if i == 0 else d
        layers.append(
            {
                "phi_e": _mlp_init(ks[3 * i], [2 * din + 1, d, d]),
                "phi_x": _mlp_init(ks[3 * i + 1], [d, d, 1]),
                "phi_h": _mlp_init(ks[3 * i + 2], [din + d, d, d]),
            }
        )
    return {"layers": layers, "out": L.dense_init(ks[-1], d, cfg.d_out)}


def egnn_apply(params, cfg: GNNConfig, batch: Dict):
    h, src, dst, emask = batch["x"], batch["src"], batch["dst"], batch["emask"]
    coords = batch["coords"]
    n = h.shape[0]
    for lp in params["layers"]:
        xi, xj = jnp.take(coords, dst, axis=0), jnp.take(coords, src, axis=0)
        diff = xi - xj
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        hi, hj = jnp.take(h, dst, axis=0), jnp.take(h, src, axis=0)
        m = _mlp(lp["phi_e"], jnp.concatenate([hi, hj, d2], axis=-1))
        m = jax.nn.silu(m)
        m = jnp.where(emask[:, None], m, 0.0)
        # coordinate update (equivariant)
        w = _mlp(lp["phi_x"], m)
        xupd = jax.ops.segment_sum(diff * w, dst, num_segments=n)
        cnt = jnp.maximum(_deg(dst, n, emask), 1.0)[:, None]
        coords = coords + xupd / cnt
        # feature update
        magg = jax.ops.segment_sum(m, dst, num_segments=n)
        h = _mlp(lp["phi_h"], jnp.concatenate([h, magg], axis=-1))
    return L.dense(params["out"], h, jnp.float32), coords


# ---------------------------------------------------------------------------
# NequIP-lite — O(3)-equivariant with restricted tensor-product paths
# (full e3nn CG products are out of scope; the restricted path set
#  {0⊗Yl→l, l⊗Y0→l, 1⊗Y1→0} is individually equivariant. See DESIGN.md.)
# ---------------------------------------------------------------------------
def _bessel_rbf(r, n_rbf, cutoff):
    # Bessel radial basis with smooth polynomial cutoff (NequIP defaults)
    n = jnp.arange(1, n_rbf + 1, dtype=jnp.float32)
    rr = jnp.maximum(r, 1e-6)
    rbf = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * rr[..., None] / cutoff) / rr[..., None]
    x = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * x**3 + 15.0 * x**4 - 6.0 * x**5  # C2-smooth cutoff
    return rbf * env[..., None]


def _y2(u):
    """5 real l=2 spherical-harmonic components of unit vector u (N,3)."""
    x, y, z = u[..., 0], u[..., 1], u[..., 2]
    c = np.sqrt(3.0)
    return jnp.stack(
        [c * x * y, c * y * z, 0.5 * (3 * z * z - 1.0), c * x * z,
         0.5 * c * (x * x - y * y)],
        axis=-1,
    )


def nequip_init(key, cfg: GNNConfig, n_species: int = 8):
    d = cfg.d_hidden
    ks = jax.random.split(key, 6 * cfg.n_layers + 3)
    layers = []
    for i in range(cfg.n_layers):
        layers.append(
            {
                # radial nets: rbf -> per-channel weights for each TP path
                "r00": _mlp_init(ks[6 * i + 0], [cfg.n_rbf, d, d]),
                "r01": _mlp_init(ks[6 * i + 1], [cfg.n_rbf, d, d]),
                "r11": _mlp_init(ks[6 * i + 2], [cfg.n_rbf, d, d]),
                "r110": _mlp_init(ks[6 * i + 3], [cfg.n_rbf, d, d]),
                "r02": _mlp_init(ks[6 * i + 4], [cfg.n_rbf, d, d]) if cfg.l_max >= 2 else None,
                "r22": _mlp_init(ks[6 * i + 5], [cfg.n_rbf, d, d]) if cfg.l_max >= 2 else None,
                "self0": L.dense_init(jax.random.fold_in(ks[6 * i], 1), d, d),
                "self1": L.dense_init(jax.random.fold_in(ks[6 * i], 2), d, d),
                "self2": L.dense_init(jax.random.fold_in(ks[6 * i], 3), d, d),
                "gate": L.dense_init(jax.random.fold_in(ks[6 * i], 4), d, 2 * d),
            }
        )
    return {
        "embed": jax.random.normal(ks[-2], (n_species, d)) * 0.5,
        "layers": layers,
        "out": _mlp_init(ks[-1], [d, d, 1]),
    }


def nequip_apply(params, cfg: GNNConfig, batch: Dict):
    """Returns (per-node energy, forces-free). Features: s (N,d), v (N,d,3),
    t (N,d,5); all channel-major."""
    src, dst, emask = batch["src"], batch["dst"], batch["emask"]
    coords, species = batch["coords"], batch["species"]
    n = coords.shape[0]
    d = cfg.d_hidden

    rij = jnp.take(coords, dst, axis=0) - jnp.take(coords, src, axis=0)
    r = jnp.sqrt(jnp.maximum(jnp.sum(rij * rij, axis=-1), 1e-12))
    u = rij / r[:, None]
    rbf = _bessel_rbf(r, cfg.n_rbf, cfg.cutoff)          # (E, n_rbf)
    y1 = u                                                # (E, 3)
    y2 = _y2(u) if cfg.l_max >= 2 else None               # (E, 5)
    valid = emask & (r < cfg.cutoff)

    s = jnp.take(params["embed"], species, axis=0)        # (N, d)
    v = jnp.zeros((n, d, 3))
    t = jnp.zeros((n, d, 5)) if cfg.l_max >= 2 else None

    def seg(x, w):
        x = jnp.where(valid.reshape((-1,) + (1,) * (x.ndim - 1)), x * w, 0.0)
        return jax.ops.segment_sum(x, dst, num_segments=n)

    for lp in params["layers"]:
        sj = jnp.take(s, src, axis=0)                     # (E, d)
        vj = jnp.take(v, src, axis=0)                     # (E, d, 3)
        w00 = _mlp(lp["r00"], rbf)                        # (E, d)
        w01 = _mlp(lp["r01"], rbf)
        w11 = _mlp(lp["r11"], rbf)
        w110 = _mlp(lp["r110"], rbf)

        # l=0 out: 0⊗Y0→0 and 1⊗Y1→0 (dot product path)
        s_new = seg(sj, w00) + seg(jnp.einsum("edk,ek->ed", vj, y1), w110)
        # l=1 out: 0⊗Y1→1 and 1⊗Y0→1
        v_new = seg(sj[:, :, None] * y1[:, None, :], w01[:, :, None]) + seg(
            vj, w11[:, :, None]
        )
        if cfg.l_max >= 2:
            tj = jnp.take(t, src, axis=0)
            w02 = _mlp(lp["r02"], rbf)
            w22 = _mlp(lp["r22"], rbf)
            t_new = seg(sj[:, :, None] * y2[:, None, :], w02[:, :, None]) + seg(
                tj, w22[:, :, None]
            )
        # self-interaction (channel mixing) + gated nonlinearity
        s_mix = L.dense(lp["self0"], s + s_new)
        v_mix = jnp.einsum("ndk,do->nok", v + v_new, lp["self1"]["w"])
        gates = L.dense(lp["gate"], jax.nn.silu(s_mix))
        g1, g0 = gates[:, :d], gates[:, d:]
        s = jax.nn.silu(s_mix + g0)
        v = v_mix * jax.nn.sigmoid(g1)[:, :, None]
        if cfg.l_max >= 2:
            t_mix = jnp.einsum("ndk,do->nok", t + t_new, lp["self2"]["w"])
            t = t_mix * jax.nn.sigmoid(g1)[:, :, None]

    energy = _mlp(params["out"], s)[:, 0]                 # invariant readout
    return energy


KINDS = {
    "gin": (gin_init, gin_apply),
    "pna": (pna_init, pna_apply),
    "egnn": (egnn_init, egnn_apply),
    "nequip": (nequip_init, nequip_apply),
}


def init(key, cfg: GNNConfig, d_feat: int):
    if cfg.kind == "nequip":
        return nequip_init(key, cfg)
    return KINDS[cfg.kind][0](key, cfg, d_feat)


def apply(params, cfg: GNNConfig, batch: Dict):
    return KINDS[cfg.kind][1](params, cfg, batch)
