"""NN building blocks: norms, RoPE, GQA attention (chunked/online-softmax),
dense FFN variants and the sort-based MoE layer.

Pure-functional: ``*_init(key, ...) -> params`` and ``*_apply(params, ...)``.
Parameters are plain dicts of jnp arrays so they stack cleanly along a
leading layer axis for ``lax.scan`` (small HLO => fast 512-device compiles).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, d_in: int, d_out: int, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / np.sqrt(d_in)
    return {"w": jax.random.normal(key, (d_in, d_out), jnp.float32) * scale}


def dense(params, x, compute_dtype=jnp.bfloat16):
    return jnp.einsum(
        "...i,io->...o", x.astype(compute_dtype), params["w"].astype(compute_dtype)
    )


def rmsnorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * params["g"]).astype(x.dtype)


def layernorm_init(d: int):
    return {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(params, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * params["g"] + params["b"]).astype(
        x.dtype
    )


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------
def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., :, None, :]  # broadcast over heads
    sin = sin[..., :, None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA), memory-efficient online-softmax over KV chunks
# ---------------------------------------------------------------------------
def _repeat_kv(k: jnp.ndarray, groups: int):
    # (B, S, KV, hd) -> (B, S, KV*groups, hd)
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, groups, hd)).reshape(
        b, s, kv * groups, hd
    )


def attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, KV, hd)
    v: jnp.ndarray,
    causal: bool = True,
    q_offset: int | jnp.ndarray = 0,
    kv_chunk: int = 1024,
    q_chunk: int = 512,
    kv_len: Optional[jnp.ndarray] = None,
):
    """Online-softmax attention; O(chunk) memory, HLO-size O(1) via scan.

    GQA is computed with grouped einsums — KV is NEVER materialized at H
    heads (perf iteration: a broadcast repeat of a seq-sharded KV cache
    forces GSPMD to re-gather the whole cache every layer; the grouped
    form keeps the cache sharded and reduces only the (small) outputs).

    ``q_offset`` is the absolute position of q[0] (decode: cache length).
    ``kv_len`` masks the valid cache prefix during decode.
    """
    b, sq, h, hd = q.shape
    _, sk, kvh, _ = k.shape
    groups = h // kvh
    scale = 1.0 / np.sqrt(hd)
    qg_all = q.reshape(b, sq, kvh, groups, hd)

    if sq == 1:
        # decode: single query against the whole cache in one pass (no scan
        # — keeps softmax psum at layer-scan depth for sharded-KV serving)
        qpos = q_offset + jnp.zeros((1,), jnp.int32)
        s = jnp.einsum("bqngd,bknd->bngqk", qg_all, k)
        s = s.astype(jnp.float32) * scale        # (b, kv, g, 1, Sk)
        kpos = jnp.arange(sk)
        if kv_len is not None:
            s = jnp.where((kpos < kv_len)[None, None, None, None], s, -jnp.inf)
        if causal:
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bngqk,bknd->bqngd", p.astype(q.dtype), v)
        return out.reshape(b, sq, h, hd).astype(q.dtype)

    # prefill/train path: repeated-KV head layout (measured better under
    # head-TP than the grouped form, which re-shards on the small KV dim)
    kr = _repeat_kv(k, groups)
    vr = _repeat_kv(v, groups)
    n_kv = max(sk // kv_chunk, 1)
    kv_chunk = sk // n_kv
    kr = kr.reshape(b, n_kv, kv_chunk, h, hd)
    vr = vr.reshape(b, n_kv, kv_chunk, h, hd)

    @jax.checkpoint
    def q_block(qb, qpos):
        # qb: (B, qc, H, hd); qpos: (qc,) absolute positions
        # checkpointed: the backward recomputes this q-chunk's kv scan
        # instead of stashing stacked (q_chunks x kv_chunks) score tensors
        # (perf iteration: cut nemotron train temp memory — EXPERIMENTS §Perf)
        def kv_step(carry, inp):
            m, l, acc = carry
            kc, vc, kidx = inp  # (B, kv_chunk, H, hd), scalar chunk index
            kpos = kidx * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhd,bkhd->bhqk", qb, kc).astype(jnp.float32) * scale
            if causal:
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None], s, -jnp.inf)
            if kv_len is not None:
                s = jnp.where((kpos < kv_len)[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", p.astype(qb.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        qc = qb.shape[1]
        m0 = jnp.full((b, h, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, qc), jnp.float32)
        acc0 = jnp.zeros((b, h, qc, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step,
            (m0, l0, acc0),
            (
                jnp.moveaxis(kr, 1, 0),
                jnp.moveaxis(vr, 1, 0),
                jnp.arange(n_kv),
            ),
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return jnp.moveaxis(out, 1, 2).astype(q.dtype)  # (B, qc, H, hd)

    n_q = max(sq // q_chunk, 1)
    q_chunk = sq // n_q
    qs = q.reshape(b, n_q, q_chunk, h, hd)

    def q_step(_, inp):
        qb, qidx = inp
        qpos = q_offset + qidx * q_chunk + jnp.arange(q_chunk)
        return None, q_block(qb, qpos)

    _, outs = jax.lax.scan(q_step, None, (jnp.moveaxis(qs, 1, 0), jnp.arange(n_q)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int):
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model),
    }


# ---------------------------------------------------------------------------
# FFN variants
# ---------------------------------------------------------------------------
def squared_relu(x):
    r = jax.nn.relu(x)
    return r * r


ACTS = {
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "relu2": squared_relu,
    "relu": jax.nn.relu,
}


def ffn_init(key, d_model: int, d_ff: int, gated: bool):
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], d_model, d_ff),
        "wo": dense_init(ks[1], d_ff, d_model),
    }
    if gated:
        p["wg"] = dense_init(ks[2], d_model, d_ff)
    return p


def ffn(params, x, act: str = "gelu", compute_dtype=jnp.bfloat16):
    h = dense(params["wi"], x, compute_dtype)
    h = ACTS[act](h)
    if "wg" in params:
        h = h * dense(params["wg"], x, compute_dtype)
    return dense(params["wo"], h, compute_dtype)


# ---------------------------------------------------------------------------
# Mixture-of-Experts: sort-free capacity dispatch (gather/scatter, no O(T*E*C)
# one-hot matmuls so HLO FLOPs stay honest for the roofline).
# ---------------------------------------------------------------------------
def moe_init(key, d_model: int, d_ff: int, n_experts: int, gated: bool):
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], d_model, n_experts, scale=0.02),
        "wi": jax.random.normal(ks[1], (n_experts, d_model, d_ff)) / np.sqrt(d_model),
        "wo": jax.random.normal(ks[2], (n_experts, d_ff, d_model)) / np.sqrt(d_ff),
    }
    if gated:
        p["wg"] = jax.random.normal(ks[3], (n_experts, d_model, d_ff)) / np.sqrt(
            d_model
        )
    return p


def moe(
    params,
    x: jnp.ndarray,  # (T, d)
    top_k: int,
    act: str = "silu",
    capacity_factor: float = 1.25,
    compute_dtype=jnp.bfloat16,
):
    """Top-k token-choice MoE with capacity-bounded scatter dispatch.

    Returns (out, aux_loss). Tokens beyond an expert's capacity are dropped
    (standard GShard semantics).
    """
    t, d = x.shape
    e = params["router"]["w"].shape[1]
    cap = int(np.ceil(t * top_k / e * capacity_factor))

    logits = dense(params["router"], x, jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # slot of each (token, k) within its expert: rank among same-expert
    # picks. Hierarchical cumsum: the big scan runs within token chunks
    # (shard-local under data-parallel sharding) and only the tiny
    # (chunks, E) totals cross shards — a flat global cumsum forced GSPMD
    # into per-layer collective chains (perf log, EXPERIMENTS §Perf).
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    n = flat_e.shape[0]
    chunks = 16 if n % 16 == 0 else 1
    oh_c = onehot.reshape(chunks, n // chunks, e)
    local = jnp.cumsum(oh_c, axis=1) - oh_c
    totals = oh_c.sum(axis=1)                         # (chunks, E)
    offs = jnp.cumsum(totals, axis=0) - totals
    rank_mat = (local + offs[:, None, :]).reshape(n, e)
    ranks = rank_mat.max(axis=-1, where=onehot > 0, initial=0)
    # position within expert buffer; overflow -> dropped
    keep = ranks < cap
    slot = jnp.where(keep, flat_e * cap + ranks, e * cap)  # sentinel row

    # scatter tokens into (E*cap + 1, d) buffer
    xk = jnp.repeat(x, top_k, axis=0)  # (T*k, d)
    buf = jnp.zeros((e * cap + 1, d), x.dtype).at[slot].set(xk)
    buf = buf[: e * cap].reshape(e, cap, d)

    h = jnp.einsum(
        "ecd,edf->ecf", buf.astype(compute_dtype), params["wi"].astype(compute_dtype)
    )
    h = ACTS[act](h)
    if "wg" in params:
        g = jnp.einsum(
            "ecd,edf->ecf",
            buf.astype(compute_dtype),
            params["wg"].astype(compute_dtype),
        )
        h = h * g
    y = jnp.einsum(
        "ecf,efd->ecd", h, params["wo"].astype(compute_dtype)
    )  # (E, cap, d)

    y_flat = y.reshape(e * cap, d)
    gathered = jnp.where(
        keep[:, None], jnp.take(y_flat, jnp.minimum(slot, e * cap - 1), axis=0), 0.0
    )
    out = (
        (gathered * gate_vals.reshape(-1)[:, None].astype(gathered.dtype))
        .reshape(t, top_k, d)
        .sum(axis=1)
    )

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[flat_e].add(1.0) / (t * top_k)
    aux = e * jnp.sum(me * ce)
    return out.astype(x.dtype), aux
