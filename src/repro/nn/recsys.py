"""MIND: Multi-Interest Network with Dynamic routing (Li et al., CIKM'19).

Pipeline: item-embedding lookup over the user's behaviour history
(EmbeddingBag — the huge sparse-table hot path), capsule dynamic routing
into ``n_interests`` interest capsules, label-aware attention for training,
sampled-softmax loss; serving scores candidates against interests with a
max-over-interests reduction.

GRASP tie-in: item popularity is Zipfian — with the table rows ordered by
popularity (the recsys analogue of DBG reordering), the leading rows form
the High Reuse Region: pinned in VMEM by ``kernels/embedding_bag`` and
replicated across chips by the distributed plan while the cold tail stays
row-sharded.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RecsysConfig
from repro.nn import layers as L


def init(key, cfg: RecsysConfig, hot_rows: int = 0):
    """``hot_rows > 0`` splits the popularity-ordered table at the GRASP
    High-Reuse boundary: ``items_hot`` (replicated across chips / pinned in
    VMEM) + ``items_cold`` (row-sharded tail). The range test ``id <
    hot_rows`` IS the paper's ABR classification."""
    ks = jax.random.split(key, 5)
    d = cfg.embed_dim
    p = {
        # shared bilinear map S for capsule routing (B2I variant)
        "s_mat": jax.random.normal(ks[1], (d, d), jnp.float32) / np.sqrt(d),
        "mlp": [
            L.dense_init(ks[2], d, cfg.d_hidden),
            L.dense_init(ks[3], cfg.d_hidden, d),
        ],
    }
    if hot_rows > 0:
        p["items_hot"] = jax.random.normal(ks[0], (hot_rows, d)) * 0.05
        p["items_cold"] = (
            jax.random.normal(ks[4], (cfg.n_items - hot_rows, d)) * 0.05
        )
    else:
        p["items"] = jax.random.normal(ks[0], (cfg.n_items, d)) * 0.05
    return p


COLD_FRACTION = 0.5  # bounded cold-path capacity (Zipf: ~8% of lookups
                     # miss a 2^18-row hot prefix; 0.5 is a safety margin)


def table_lookup(params, ids):
    """GRASP-classified lookup: replicated hot prefix (zero collective) vs
    a *compacted* bounded gather of the row-sharded cold tail.

    A naive where(hot, cold) still pays the sharded-gather collective for
    every id (measured: no win); compaction makes the collective
    proportional to the actual cold count — the same bounded cold fixup the
    hot_gather Pallas kernel uses. Overflow beyond capacity reads row 0 of
    the cold shard (graceful degradation, like MoE token dropping)."""
    if "items_hot" not in params:
        return jnp.take(params["items"], ids, axis=0)
    h = params["items_hot"].shape[0]
    d = params["items_hot"].shape[1]
    shape = ids.shape
    flat = ids.reshape(-1)
    n = flat.shape[0]
    cap = max(int(n * COLD_FRACTION) // 256 * 256, 256)

    hot_rows = jnp.take(params["items_hot"], jnp.clip(flat, 0, h - 1), axis=0)
    cold = flat >= h
    pos = jnp.cumsum(cold.astype(jnp.int32)) - 1
    slot = jnp.where(cold & (pos < cap), pos, cap)
    comp = jnp.zeros((cap + 1,), flat.dtype).at[slot].set(
        jnp.maximum(flat - h, 0)
    )
    cold_rows = jnp.take(params["items_cold"], comp[:cap], axis=0)
    cold_rows = jnp.concatenate(
        [cold_rows, jnp.zeros((1, d), cold_rows.dtype)], axis=0
    )
    fix = jnp.take(cold_rows, jnp.minimum(slot, cap), axis=0)
    out = jnp.where(cold[:, None], fix, hot_rows)
    return out.reshape(shape + (d,))


def _squash(x, axis=-1, eps=1e-9):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1.0 + n2)) * x / jnp.sqrt(n2 + eps)


def embedding_lookup(table, ids, impl: str = "jnp", plan=None):
    """(B, H) ids -> (B, H, d). ``impl='pallas_hot'`` uses the two-tier
    VMEM-pinned kernel with the GraspPlan hot prefix."""
    if impl == "jnp":
        return table_lookup(table, ids) if isinstance(table, dict) else jnp.take(table, ids, axis=0)
    if impl == "pallas_hot":
        from repro.kernels.embedding_bag import ops as bag_ops

        b, h = ids.shape
        out = bag_ops.hot_lookup(table, ids.reshape(-1), plan=plan)
        return out.reshape(b, h, -1)
    raise ValueError(impl)


def user_interests(params, cfg: RecsysConfig, hist: jnp.ndarray,
                   hist_mask: jnp.ndarray, impl: str = "jnp", plan=None):
    """hist (B, H) item ids -> interest capsules (B, K, d).

    Dynamic routing (capsule_iters rounds) with fixed random-ish init
    logits derived from item ids (deterministic, matches MIND's B2I)."""
    if impl == "jnp":
        e = table_lookup(params, hist)                               # (B, H, d)
    else:
        e = embedding_lookup(params["items"], hist, impl, plan)
    return user_interests_from_emb(params, cfg, e, hist, hist_mask)


def user_interests_from_emb(params, cfg: RecsysConfig, e: jnp.ndarray,
                            hist: jnp.ndarray, hist_mask: jnp.ndarray):
    """Routing from pre-gathered history embeddings ``e`` (B, H, d).

    The serving tier (``repro.serve``) gathers ``e`` through its
    GRASP-managed embedding cache and hands it here, so the capsule math is
    shared between the parameter-table and cache-fed paths."""
    k = cfg.n_interests
    e = jnp.where(hist_mask[..., None], e, 0.0)
    eh = jnp.einsum("bhd,de->bhe", e, params["s_mat"])           # bilinear map

    # deterministic routing-logit init (hash of item id x capsule)
    binit = jnp.sin(hist[..., None].astype(jnp.float32) * (1.0 + jnp.arange(k)))
    logits = binit  # (B, H, K)

    interests = None
    for _ in range(cfg.capsule_iters):
        w = jax.nn.softmax(logits, axis=-1)                      # (B, H, K)
        w = jnp.where(hist_mask[..., None], w, 0.0)
        z = jnp.einsum("bhk,bhd->bkd", w, eh)
        interests = _squash(z)                                   # (B, K, d)
        logits = logits + jnp.einsum("bkd,bhd->bhk", interests, eh)

    # per-interest MLP refinement
    h = L.dense(params["mlp"][0], interests, jnp.float32)
    h = jax.nn.relu(h)
    return interests + L.dense(params["mlp"][1], h, jnp.float32)


def score_candidates(interests: jnp.ndarray, cand_emb: jnp.ndarray):
    """(B, K, d) interests x (B, C, d) candidates -> (B, C) max-over-interest
    scores (MIND serving reduction)."""
    scores = jnp.einsum("bkd,bcd->bkc", interests, cand_emb)
    return scores.max(axis=1)


def label_aware_attention(interests, target_emb, p: float = 2.0):
    """MIND label-aware attention: target attends over interests."""
    scores = jnp.einsum("bkd,bd->bk", interests, target_emb)
    w = jax.nn.softmax(scores * p, axis=-1)
    return jnp.einsum("bk,bkd->bd", w, interests)


def loss_fn(params, cfg: RecsysConfig, batch: Dict, impl: str = "jnp", plan=None):
    """Sampled softmax: target vs shared negatives.

    batch: hist (B,H) int32, hist_mask (B,H) bool, target (B,) int32,
           negatives (Neg,) int32.
    """
    interests = user_interests(params, cfg, batch["hist"], batch["hist_mask"],
                               impl, plan)
    tgt = table_lookup(params, batch["target"])                  # (B, d)
    user = label_aware_attention(interests, tgt)                 # (B, d)
    neg = table_lookup(params, batch["negatives"])               # (Neg, d)
    pos_logit = jnp.sum(user * tgt, axis=-1, keepdims=True)      # (B, 1)
    neg_logit = user @ neg.T                                     # (B, Neg)
    logits = jnp.concatenate([pos_logit, neg_logit], axis=-1)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -logp[:, 0].mean()


def serve_scores(params, cfg: RecsysConfig, batch: Dict, impl: str = "jnp",
                 plan=None):
    """Online inference: score each request's candidate set.

    batch: hist (B,H), hist_mask (B,H), candidates (B, C) int32.
    Max-over-interests scoring (MIND serving)."""
    interests = user_interests(params, cfg, batch["hist"], batch["hist_mask"],
                               impl, plan)
    cand = table_lookup(params, batch["candidates"])               # (B, C, d)
    return score_candidates(interests, cand)                       # (B, C)


def retrieval_scores(params, cfg: RecsysConfig, batch: Dict, impl: str = "jnp",
                     plan=None):
    """One query against n_candidates (batched dot, no loop): the
    ``retrieval_cand`` shape. candidates (C,) int32 (C ~ 1e6)."""
    interests = user_interests(params, cfg, batch["hist"], batch["hist_mask"],
                               impl, plan)                         # (1, K, d)
    cand = table_lookup(params, batch["candidates"])               # (C, d)
    scores = jnp.einsum("bkd,cd->bkc", interests, cand)
    return scores.max(axis=1)                                      # (1, C)
