"""Decoder-only transformer LM (dense + MoE), GQA + RoPE, scan-over-layers.

Layer parameters are stacked on a leading axis and iterated with
``jax.lax.scan`` so the HLO stays O(1) in depth — essential for compiling
96-layer configs on 512 placeholder devices. ``jax.checkpoint`` (remat)
wraps the scanned body when ``cfg.remat``.

Entry points used by launch/dryrun and the trainer:
  init(key, cfg)                         -> params
  forward(params, cfg, tokens)           -> logits
  loss_fn(params, cfg, batch)            -> scalar loss
  prefill(params, cfg, tokens)           -> (last logits, KVCache)
  decode_step(params, cfg, cache, token) -> (logits, KVCache)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import LMConfig
from repro.nn import layers as L


def _norm_init(cfg, d):
    return L.rmsnorm_init(d) if cfg.norm == "rmsnorm" else L.layernorm_init(d)


def _norm(cfg, p, x):
    return L.rmsnorm(p, x) if cfg.norm == "rmsnorm" else L.layernorm(p, x)


def init_layer(key, cfg: LMConfig):
    ks = jax.random.split(key, 4)
    p = {
        "attn": L.gqa_init(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim),
        "ln1": _norm_init(cfg, cfg.d_model),
        "ln2": _norm_init(cfg, cfg.d_model),
    }
    if cfg.moe:
        p["moe"] = L.moe_init(ks[1], cfg.d_model, cfg.d_ff, cfg.moe.n_experts, cfg.gated)
    else:
        p["ffn"] = L.ffn_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated)
    return p


def init(key, cfg: LMConfig):
    kemb, klayers, kout = jax.random.split(key, 3)
    layer_keys = jax.random.split(klayers, cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    return {
        "embed": jax.random.normal(kemb, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02,
        "layers": stacked,
        "ln_f": _norm_init(cfg, cfg.d_model),
        "lm_head": L.dense_init(kout, cfg.d_model, cfg.vocab, scale=0.02),
    }


def _attn_block(cfg: LMConfig, p, x, positions, cache_kv=None, kv_len=None):
    """Returns (attn output, (k, v) of this call)."""
    from repro.dist.sharding import constrain

    b, s, d = x.shape
    q = L.dense(p["wq"], x).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = L.dense(p["wk"], x).reshape(b, s, cfg.n_kv, cfg.head_dim)
    v = L.dense(p["wv"], x).reshape(b, s, cfg.n_kv, cfg.head_dim)
    q = L.rope(q, positions, cfg.rope_theta)
    k = L.rope(k, positions, cfg.rope_theta)
    if s > 1:
        # pin the attention layout: batch over data axes, heads over model,
        # full sequence — otherwise SPMD can fall back to batch replication
        # inside the rematted backward (observed on the 512-dev dry-run)
        bax = ("pod", "data")
        q = constrain(q, bax, None, "model", None)
        k = constrain(k, bax, None, None, None)
        v = constrain(v, bax, None, None, None)
    if cache_kv is not None:
        ck, cv = cache_kv  # (B, S_max, KV, hd)
        out = L.attention(q, ck, cv, causal=False, kv_len=kv_len)
    else:
        out = L.attention(q, k, v, causal=True)
    out = out.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return L.dense(p["wo"], out), (k, v)


def _layer_fwd(cfg: LMConfig, lp, x, positions, cache=None, kv_len=None):
    h, kv = _attn_block(
        cfg, lp["attn"], _norm(cfg, lp["ln1"], x), positions, cache, kv_len
    )
    x = x + h
    hin = _norm(cfg, lp["ln2"], x)
    if cfg.moe:
        b, s, d = hin.shape
        out, aux = L.moe(
            lp["moe"],
            hin.reshape(b * s, d),
            top_k=cfg.moe.top_k,
            act=cfg.act,
            capacity_factor=cfg.moe.capacity_factor,
        )
        out = out.reshape(b, s, d)
    else:
        out, aux = L.ffn(lp["ffn"], hin, act=cfg.act), 0.0
    return x + out, aux, kv


def _constrain_seq(cfg, x):
    """Megatron-style sequence parallelism: between blocks the activation
    stash is sharded over the model axis along S (memory / chips budget for
    the 340B-class archs). GSPMD inserts the gather/scatter collectives."""
    if getattr(cfg, "seq_shard", False):
        from repro.dist.sharding import constrain

        return constrain(x, ("pod", "data"), "model", None)
    return x


def trunk(params, cfg: LMConfig, tokens: jnp.ndarray):
    """tokens (B, S) -> final hidden states (B, S, d), aux loss.

    ``cfg.layer_groups > 1`` enables sqrt-L nested-group remat: the outer
    scan checkpoints only group boundaries and the inner scan is recomputed
    per group in the backward — stash (G + L/G) activations instead of L
    (the 340B-class memory budget; EXPERIMENTS.md §Perf)."""
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _constrain_seq(cfg, x)

    def body(carry, lp):
        x, aux = carry
        x, a, _ = _layer_fwd(cfg, lp, x, positions)
        return (_constrain_seq(cfg, x), aux + a), None

    groups = getattr(cfg, "layer_groups", 1)
    if groups > 1 and cfg.n_layers % groups == 0:
        per = cfg.n_layers // groups
        grouped = jax.tree_util.tree_map(
            lambda a: a.reshape((groups, per) + a.shape[1:]), params["layers"]
        )

        @jax.checkpoint
        def group_body(carry, gp):
            out, _ = jax.lax.scan(body, carry, gp)
            return out, None

        (x, aux), _ = jax.lax.scan(group_body, (x, 0.0), grouped)
    else:
        scan_body = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(scan_body, (x, 0.0), params["layers"])
    return _norm(cfg, params["ln_f"], x), aux


def forward(params, cfg: LMConfig, tokens: jnp.ndarray):
    """tokens (B, S) -> logits (B, S, vocab). Returns (logits, aux_loss).
    Materializes full logits — use only at small scale (smoke tests)."""
    x, aux = trunk(params, cfg, tokens)
    logits = L.dense(params["lm_head"], x, jnp.float32)
    return logits, aux


LOSS_CHUNK = 128  # sequence positions per unrolled CE chunk (perf: 512->128
                  # cut peak logits temp 4x; see EXPERIMENTS.md §Perf)


def loss_fn(params, cfg: LMConfig, batch):
    """Chunked cross-entropy: the (B, S, vocab) logits tensor is never
    materialized (vocab up to 256k x 1M tokens would be TBs). The head
    matmul + softmax run per sequence chunk under jax.checkpoint, so the
    backward recomputes chunk logits instead of storing them. Logits are
    bf16 with f32 softmax statistics — the backward's dlogits/dx
    all-reduces then move bf16 (half the dominant collective)."""
    x, aux = trunk(params, cfg, batch["tokens"])
    b, s, d = x.shape
    labels = batch["labels"]
    n_chunks = max(s // LOSS_CHUNK, 1)

    @jax.checkpoint
    def chunk_nll(xc, lc):
        logits = L.dense(params["lm_head"], xc, jnp.bfloat16)
        m = jax.lax.stop_gradient(logits.max(axis=-1, keepdims=True))
        shifted = (logits - m).astype(jnp.float32)
        lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
        tgt = jnp.take_along_axis(shifted, lc[..., None], axis=-1)[..., 0]
        return (lse - tgt).sum()

    xs = x.reshape(b, n_chunks, s // n_chunks, d)
    ls = labels.reshape(b, n_chunks, s // n_chunks)
    total = 0.0
    for i in range(n_chunks):  # unrolled: collectives stay loop-free in HLO
        total = total + chunk_nll(xs[:, i], ls[:, i])
    loss = total / (b * s)
    return loss + 0.01 * aux


# ---------------------------------------------------------------------------
# Serving: prefill + decode with KV cache
# ---------------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class KVCache:
    k: jnp.ndarray   # (L, B, S_max, KV, hd)
    v: jnp.ndarray
    length: jnp.ndarray  # () int32 — valid prefix


def init_cache(cfg: LMConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.head_dim)
    return KVCache(
        k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype),
        length=jnp.zeros((), jnp.int32),
    )


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, max_len: Optional[int] = None):
    """Full-sequence forward; returns (logits at last position, cache)."""
    b, s = tokens.shape
    max_len = max_len or s
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.bfloat16)
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))

    def body(x, lp):
        x, _, (k, v) = _layer_fwd(cfg, lp, x, positions)
        return _constrain_seq(cfg, x), (k, v)

    x = _constrain_seq(cfg, x)
    x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    x = _norm(cfg, params["ln_f"], x[:, -1:])
    logits = L.dense(params["lm_head"], x, jnp.float32)[:, 0]
    pad = max_len - s
    ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    return logits, KVCache(k=ks, v=vs, length=jnp.int32(s))


def decode_step(params, cfg: LMConfig, cache: KVCache, token: jnp.ndarray):
    """token (B,) int32 -> (logits (B,vocab), updated cache). One new token
    against a long KV cache — the ``decode_32k`` / ``long_500k`` step."""
    b = token.shape[0]
    x = jnp.take(params["embed"], token[:, None], axis=0).astype(jnp.bfloat16)
    positions = jnp.broadcast_to(cache.length[None, None], (b, 1))

    def layer(x, inp):
        lp, ck, cv = inp
        xb = _norm(cfg, lp["ln1"], x)
        q = L.dense(lp["attn"]["wq"], xb).reshape(b, 1, cfg.n_heads, cfg.head_dim)
        k = L.dense(lp["attn"]["wk"], xb).reshape(b, 1, cfg.n_kv, cfg.head_dim)
        v = L.dense(lp["attn"]["wv"], xb).reshape(b, 1, cfg.n_kv, cfg.head_dim)
        q = L.rope(q, positions, cfg.rope_theta)
        k = L.rope(k, positions, cfg.rope_theta)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, cache.length, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, cache.length, 0, 0))
        out = L.attention(q, ck, cv, causal=False, kv_len=cache.length + 1)
        out = out.reshape(b, 1, cfg.n_heads * cfg.head_dim)
        x = x + L.dense(lp["attn"]["wo"], out)
        hin = _norm(cfg, lp["ln2"], x)
        if cfg.moe:
            o, _ = L.moe(lp["moe"], hin.reshape(b, -1), top_k=cfg.moe.top_k, act=cfg.act)
            x = x + o.reshape(b, 1, -1)
        else:
            x = x + L.ffn(lp["ffn"], hin, act=cfg.act)
        return x, (ck, cv)

    x, (ks, vs) = jax.lax.scan(layer, x, (params["layers"], cache.k, cache.v))
    x = _norm(cfg, params["ln_f"], x)
    logits = L.dense(params["lm_head"], x, jnp.float32)[:, 0]
    return logits, KVCache(k=ks, v=vs, length=cache.length + 1)
