"""repro.serve — GRASP-managed embedding cache + continuous-batching
inference subsystem.

The online tier of the reproduction: ``cache`` (two-region GRASP embedding
cache), ``scheduler`` (continuous batching, admission control, deadlines,
shed load), ``metrics`` (hit/latency accounting + JSON snapshots) and
``engine`` (recsys/GNN/LM serving drivers). See README.md in this
directory for the architecture; ``repro.gateway`` puts these engines
behind a thread-pumped RPC front-end.
"""
from repro.serve.cache import (
    CacheConfig,
    EmbeddingCache,
    LookupStats,
    SnapshotError,
)
from repro.serve.metrics import LatencyHistogram, ServeMetrics
from repro.serve.refcache import ReferenceEmbeddingCache
from repro.serve.scheduler import (
    ContinuousBatcher,
    Request,
    SchedulerConfig,
    VirtualClock,
)

__all__ = [
    "CacheConfig",
    "EmbeddingCache",
    "LookupStats",
    "ReferenceEmbeddingCache",
    "SnapshotError",
    "LatencyHistogram",
    "ServeMetrics",
    "ContinuousBatcher",
    "Request",
    "SchedulerConfig",
    "VirtualClock",
]
