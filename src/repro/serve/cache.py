"""GRASP-managed two-region embedding cache (the serving tier).

The paper pins the High Reuse Region of the Property Array against
thrashing and leaves the rest of the cache flexible. A production
embedding-serving cache has exactly that structure, realised in software:

  hot region   the leading ``hot_size`` rows of the popularity/degree-
               ordered table, permanently device-resident ("pinned" — no
               eviction can touch them). Batched reads go through the
               ``kernels.hot_gather`` Pallas kernel, whose constant
               index_map keeps the block VMEM-resident across the grid.
  cold region  ``cold_slots`` flexible rows managed by an RRPV scheme
               mirroring ``core.policies``: SRRIP insertion at RRPV=6,
               hit promotion to MRU, victim = aged max-RRPV slot. With a
               ``GraspPlan`` attached, insertion/promotion follow the
               paper's Table II instead (Moderate->6 with gradual
               promotion, Low->7), so tail rows cannot displace the
               Moderate Reuse Region.

Sizing comes from a *byte* budget via ``core.plan.entries_for_budget`` —
the same helper the distributed hot-replica plan uses — split between the
regions by ``hot_fraction``. ``hot_fraction=0`` disables pinning entirely
and yields the unpinned RRPV/LRU baselines the smoke benchmark compares
against.

Metadata (slot maps, RRPV counters) lives on the host; row data lives in
device arrays. ``lookup`` is batched: unique cold misses are fetched from
the backing table once (the "HBM gather") and scattered into the cold
block, so duplicate ids inside one batch cost one fill.

The lookup hot path is fully vectorized. Victim selection for a batch of
k misses exploits that RRPV aging adds the *same* delta to every slot, so
relative order never changes: in "deficit" keys (``RRPV_MAX - rrpv``) the
sequential evict loop is exactly repeated extract-min (first index on
ties) with re-insertion at ``min + 1``, which a short per-level loop
computes without per-miss Python. LRU victims are a stable argsort of the
timestamps. Both reproduce the retained reference loop implementation
(``serve.refcache``) bit-for-bit — outputs, counters, and metadata —
which the perf bench and the randomized equivalence tests assert. A host
mirror of the cold block (and the backing table itself for the hot
region's no-kernel path) keeps batch assembly free of device→host copies.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zlib
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import hotset
from repro.core import plan as plan_mod
from repro.core.policies import RRPV_LONG, RRPV_MAX
from repro.serve.metrics import ServeMetrics

LANE = 128

# bump on any change to the snapshot layout; restore refuses other versions
SNAPSHOT_VERSION = 1


class SnapshotError(ValueError):
    """Snapshot rejected: wrong version, shape mismatch, or bad checksum."""


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    budget_bytes: int          # total device budget for both regions
    hot_fraction: float = 0.5  # share of budget pinned; 0 => unpinned baseline
    policy: str = "rrpv"       # cold-region scheme: "rrpv" | "lru"
    use_kernel: bool = True    # Pallas hot_gather for the pinned region
    tile_e: int = 512          # kernel edge-tile (batch is padded up to it)
    interpret: bool = True     # CPU container; False on real TPUs


@dataclasses.dataclass(frozen=True)
class LookupStats:
    hot_hits: int = 0
    cold_hits: int = 0
    misses: int = 0     # unique fills + bypassed references
    bypassed: int = 0   # references served straight from the backing store

    @property
    def total(self) -> int:
        return self.hot_hits + self.cold_hits + self.misses

    @property
    def hit_rate(self) -> float:
        return (self.hot_hits + self.cold_hits) / self.total if self.total else 0.0


class EmbeddingCache:
    """Two-region device cache over a popularity-ordered embedding table.

    ``table`` (N, d) float32 is the backing store (HBM/host tier; row order
    = descending expected reuse, the DBG/popularity layout every other tier
    of this repo assumes). ``degree`` optionally caps the pinned region at
    the paper's hot-vertex count (degree >= average) so a huge budget never
    pins provably-cold rows. ``plan`` switches the cold region from plain
    SRRIP to GRASP Table II hint-steered insertion/promotion.
    """

    def __init__(
        self,
        table: np.ndarray,
        config: CacheConfig,
        degree: Optional[np.ndarray] = None,
        plan: Optional[plan_mod.GraspPlan] = None,
        metrics: Optional[ServeMetrics] = None,
    ) -> None:
        table = np.ascontiguousarray(np.asarray(table, np.float32))
        if table.ndim != 2:
            raise ValueError("table must be (N, d)")
        self.table = table
        self.num_rows, self.dim = table.shape
        self.row_bytes = self.dim * table.itemsize
        self.config = config
        self.plan = plan
        self.metrics = metrics if metrics is not None else ServeMetrics()

        capacity = plan_mod.entries_for_budget(
            config.budget_bytes, self.row_bytes, max_entries=self.num_rows
        )
        hot = 0
        if config.hot_fraction > 0:
            hot = plan_mod.entries_for_budget(
                int(config.budget_bytes * config.hot_fraction),
                self.row_bytes,
                max_entries=capacity,
            )
            if degree is not None:
                # never pin more rows than are actually hot (paper Sec. II-A)
                hot = min(hot, int(hotset.hot_mask(np.asarray(degree)).sum()))
        self.hot_size = int(hot)
        self.cold_slots = int(capacity - hot)
        # NB: no plan is attached by default. Measured on the zipf smoke
        # stream, Table II hint-steered cold insertion *loses* to plain
        # SRRIP here (~-2pt hit rate): the clamped tail id carries real
        # mass but classifies as Low and thrashes at RRPV=7. Matches the
        # paper's own point — pin the hot region, keep the rest flexible.

        # --- device-resident row data ---------------------------------
        d_pad = (self.dim + LANE - 1) // LANE * LANE
        self._d_pad = d_pad
        if self.hot_size > 0:
            self._hot_block = jnp.asarray(
                np.pad(table[: self.hot_size], ((0, 0), (0, d_pad - self.dim)))
            )
        else:
            self._hot_block = None
        self._cold_rows = jnp.zeros((max(self.cold_slots, 1), self.dim),
                                    jnp.float32)
        # host mirror of the cold block: batch assembly reads this instead
        # of round-tripping the whole device cold region per lookup. The
        # device copy is refreshed lazily (one fused transfer) via
        # ``cold_rows_device`` — eager per-fill scatters would recompile
        # for every distinct fill-count shape
        self._cold_rows_host = np.zeros((max(self.cold_slots, 1), self.dim),
                                        np.float32)
        self._cold_rows_dirty = False

        # --- host-side cold-region metadata ---------------------------
        cs = self.cold_slots
        self._slot_id = np.full(cs, -1, np.int64)        # slot -> row id
        self._slot_rrpv = np.full(cs, RRPV_MAX, np.int64)
        self._slot_ts = np.zeros(cs, np.int64)           # LRU timestamps
        self._id_slot = np.full(self.num_rows, -1, np.int64)
        self._clock = 0
        self._resident = 0               # occupied cold slots, incremental

    # ------------------------------------------------------------------
    @property
    def capacity(self) -> int:
        return self.hot_size + self.cold_slots

    @property
    def pin_ratio(self) -> float:
        return self.hot_size / self.capacity if self.capacity else 0.0

    def _hint(self, rid: int) -> int:
        """2-bit GRASP reuse hint for a row id (0 hot / 1 moderate / 2 low)."""
        if self.plan is None:
            return 3  # "default" — plain SRRIP handling
        return int(self.plan.classify_elem(np.int64(rid)))

    def _insert_rrpv(self, rid: int) -> int:
        h = self._hint(rid)
        if h == 1:
            return RRPV_LONG
        if h == 2:
            return RRPV_MAX
        return RRPV_LONG  # SRRIP default insertion

    def _promote(self, slots: np.ndarray) -> None:
        if self.config.policy == "lru":
            self._slot_ts[slots] = self._clock
            return
        if self.plan is None:
            self._slot_rrpv[slots] = 0
            return
        # GRASP Table II: Moderate/Low hits promote gradually (decrement)
        hints = self.plan.classify_elem(self._slot_id[slots])
        grad = np.maximum(self._slot_rrpv[slots] - 1, 0)
        self._slot_rrpv[slots] = np.where(hints >= 1, grad, 0)
        self._slot_ts[slots] = self._clock

    def _evict_one(self) -> int:
        """Pick a victim slot (cold region only — hot rows are pinned)."""
        if self.config.policy == "lru":
            return int(np.argmin(self._slot_ts))
        mx = self._slot_rrpv.max()
        if mx < RRPV_MAX:
            self._slot_rrpv += RRPV_MAX - mx  # age the whole region
        return int(np.argmax(self._slot_rrpv))

    def _insert_one(self, rid: int) -> int:
        """Sequential insert (the reference semantics; used when a GraspPlan
        steers per-id insertion RRPVs, where victim choice depends on the
        id stream order and cannot be batched)."""
        v = self._evict_one()
        old = self._slot_id[v]
        if old >= 0:
            self._id_slot[old] = -1
        else:
            self._resident += 1
        self._slot_id[v] = rid
        self._id_slot[rid] = v
        self._slot_rrpv[v] = self._insert_rrpv(int(rid))
        self._slot_ts[v] = self._clock
        return v

    # --- batched victim selection (bit-equal to the _evict_one loop) ---
    def _select_victims_rrpv(self, k: int) -> np.ndarray:
        """k RRPV victims in eviction order, without per-miss Python.

        Aging adds one uniform delta to every slot, so relative order is
        invariant: in absolute "deficit" keys (RRPV_MAX - rrpv, plus total
        aging so far) the sequential loop is exactly: repeatedly take the
        minimum key (first index on ties), re-inserting the victim at
        min + 1 (SRRIP insertion, one step from eviction). All slots tied
        at the current minimum are consumed in index order before the
        level rises, so one numpy step per *level* — not per miss —
        replays the loop exactly, re-evictions of same-batch fills
        included.
        """
        cur = (RRPV_MAX - self._slot_rrpv).astype(np.int64)  # absolute keys
        victims = np.empty(k, np.int64)
        got, level = 0, np.int64(0)
        while got < k:
            level = cur.min()
            cand = np.flatnonzero(cur == level)
            t = min(cand.size, k - got)
            victims[got:got + t] = cand[:t]
            cur[cand[:t]] = level + 1
            got += t
        # fold the accumulated aging back into stored RRPVs: final deficit
        # of every slot is its key minus the last extraction level
        self._slot_rrpv[:] = RRPV_MAX - (cur - level)
        return victims

    def _select_victims_lru(self, k: int) -> np.ndarray:
        """k LRU victims in eviction order: slots not touched this lookup,
        oldest first (stable sort = argmin's first-index tie-break). Once
        every slot carries the current clock, argmin degenerates to slot 0
        — same as the sequential loop."""
        order = np.argsort(self._slot_ts, kind="stable")
        stale = order[self._slot_ts[order] < self._clock]
        # beyond the stale set every slot holds the current clock, where
        # argmin (= the sequential victim) is always slot 0 — the zeros
        t = min(stale.size, k)
        victims = np.zeros(k, np.int64)
        victims[:t] = stale[:t]
        return victims

    def _apply_inserts(self, victims: np.ndarray, rids: np.ndarray) -> None:
        """Batched metadata update for inserting rids[i] -> victims[i] in
        order. When a slot repeats within the batch (more misses than the
        eviction dynamics keep resident), the LAST rid wins and every
        earlier same-batch rid ends displaced — exactly the sequential
        outcome."""
        k = victims.size
        uniq_slots, rev_idx = np.unique(victims[::-1], return_index=True)
        last_idx = k - 1 - rev_idx           # last occurrence of each slot
        old = self._slot_id[uniq_slots]
        self._resident += int((old < 0).sum())
        self._id_slot[old[old >= 0]] = -1    # pre-batch occupants out
        displaced = np.ones(k, bool)
        displaced[last_idx] = False
        self._id_slot[rids[displaced]] = -1  # same-batch displaced stay out
        winners = rids[last_idx]
        self._slot_id[uniq_slots] = winners
        self._id_slot[winners] = uniq_slots
        if self.config.policy == "lru":
            # rrpv aging/insertion already folded in by _select_victims_rrpv
            # on the rrpv path; LRU only stamps the insertion value
            self._slot_rrpv[victims] = RRPV_LONG
        self._slot_ts[victims] = self._clock

    def _fill_rows(self, victims: np.ndarray, rids: np.ndarray) -> None:
        """One batched backing-store gather into the host mirror for a
        batch of fills; re-used slots keep only their final occupant's
        row. The device copy is invalidated, not written — lookup serves
        from the mirror, so the device block is only materialized when a
        device consumer asks for it."""
        k = victims.size
        uniq_slots, rev_idx = np.unique(victims[::-1], return_index=True)
        winners = rids[k - 1 - rev_idx]
        self._cold_rows_host[uniq_slots] = self.table[winners]
        self._cold_rows_dirty = True

    def cold_rows_device(self) -> jnp.ndarray:
        """The cold block as a device array, refreshed from the host
        mirror in one fused update when fills have made it stale."""
        if self._cold_rows_dirty:
            self._cold_rows = jnp.asarray(self._cold_rows_host)
            self._cold_rows_dirty = False
        return self._cold_rows

    # ------------------------------------------------------------------
    def lookup(self, ids) -> Tuple[jnp.ndarray, LookupStats]:
        """Batched read: (B,) int ids -> ((B, d) float32, LookupStats).

        The result always equals ``table[ids]`` — the cache changes where
        rows are read from, never their values.
        """
        ids = np.asarray(ids, np.int64).reshape(-1)
        b = ids.shape[0]
        if b == 0:
            # empty batch: no clock tick, no metadata churn — just an
            # all-zero LookupStats and the gauges
            return self._finish(np.zeros((0, self.dim), np.float32),
                                LookupStats())
        if ids.min() < 0 or ids.max() >= self.num_rows:
            raise IndexError("id out of range")
        self._clock += 1
        hot_mask = ids < self.hot_size
        hot_hits = int(hot_mask.sum())

        cold_ids = ids[~hot_mask]
        uniq = np.unique(cold_ids)
        n_fill = 0
        if uniq.size:
            resident = self._id_slot[uniq] >= 0
            hit_slots = self._id_slot[uniq[resident]]
            if hit_slots.size:
                self._promote(hit_slots)
            miss_ids = uniq[~resident]
            if miss_ids.size and self.cold_slots > 0:
                n_fill = int(miss_ids.size)
                if self.plan is None:
                    if self.config.policy == "lru":
                        victims = self._select_victims_lru(n_fill)
                    else:
                        victims = self._select_victims_rrpv(n_fill)
                    self._apply_inserts(victims, miss_ids)
                else:
                    victims = np.fromiter(
                        (self._insert_one(int(r)) for r in miss_ids),
                        np.int64, n_fill)
                self._fill_rows(victims, miss_ids)

        # --- assemble the batch (host-only reads) ---------------------
        out = np.zeros((b, self.dim), np.float32)
        if self.hot_size > 0 and hot_hits:
            out[hot_mask] = self._gather_hot(ids, hot_mask)
        cold_mask = ~hot_mask
        slots = np.where(cold_mask, self._id_slot[ids], -1)
        served = cold_mask & (slots >= 0)
        if served.any():
            out[served] = self._cold_rows_host[slots[served]]
        byp = cold_mask & (slots < 0)
        if byp.any():
            out[byp] = self.table[ids[byp]]

        byp_refs = int(byp.sum())
        misses = n_fill + byp_refs
        cold_hits = int(cold_mask.sum()) - misses
        stats = LookupStats(hot_hits=hot_hits, cold_hits=cold_hits,
                            misses=misses, bypassed=byp_refs)
        return self._finish(out, stats)

    def _finish(self, out: np.ndarray, stats: LookupStats):
        m = self.metrics
        m.count("hot_hits", stats.hot_hits)
        m.count("cold_hits", stats.cold_hits)
        m.count("misses", stats.misses)
        m.count("bypassed", stats.bypassed)
        m.gauge("pin_ratio", self.pin_ratio)
        m.gauge("cold_resident", self._resident)
        return jnp.asarray(out), stats

    def _gather_hot(self, ids: np.ndarray, hot_mask: np.ndarray) -> np.ndarray:
        """Read the hot references of a batch from the pinned block."""
        if not self.config.use_kernel:
            # the backing table IS the hot block (unpadded): a pure host
            # gather, no device→host copy of the pinned region
            return self.table[ids[hot_mask]]
        from repro.kernels.hot_gather.hot_gather import hot_gather_hot_part

        tile = self.config.tile_e
        e_pad = (len(ids) + tile - 1) // tile * tile
        idx = np.where(hot_mask, ids, -1).astype(np.int32)  # misses -> 0 rows
        idx = np.pad(idx, (0, e_pad - len(ids)), constant_values=-1)
        rows = hot_gather_hot_part(
            self._hot_block, jnp.asarray(idx), tile_e=tile,
            interpret=self.config.interpret,
        )
        return np.asarray(rows)[: len(ids), : self.dim][hot_mask]

    # -- warm-restart snapshots ----------------------------------------
    def _snapshot_checksum(self, geometry: Dict, state: Dict) -> int:
        """crc32 over the canonical byte serialization of the snapshot
        payload — cheap, and plenty to catch truncated/garbled files."""
        blob = json.dumps({"geometry": geometry, "state": state},
                          sort_keys=True).encode()
        return zlib.crc32(blob) & 0xFFFFFFFF

    def snapshot(self) -> Dict:
        """Serialize the cache's *learned* state: which rows are resident
        where, and the recency/RRPV metadata that took a whole request
        stream to converge. Row data is NOT serialized — the backing table
        is the source of truth, so restore re-gathers resident rows from
        it (one batched fill) and the hot region rebuilds from the table
        prefix. Version-stamped and checksummed; restore validates both.
        """
        geometry = {
            "num_rows": self.num_rows,
            "dim": self.dim,
            "hot_size": self.hot_size,
            "cold_slots": self.cold_slots,
            "policy": self.config.policy,
        }
        state = {
            "slot_id": self._slot_id.tolist(),
            "slot_rrpv": self._slot_rrpv.tolist(),
            "slot_ts": self._slot_ts.tolist(),
            "clock": int(self._clock),
        }
        return {
            "version": SNAPSHOT_VERSION,
            "geometry": geometry,
            "state": state,
            "checksum": self._snapshot_checksum(geometry, state),
        }

    def restore(self, snap: Dict) -> None:
        """Rebuild hot-set/cold-region state from ``snapshot()`` output.

        Raises ``SnapshotError`` on version/geometry/checksum mismatch —
        a stale or corrupt snapshot must fall back to a cold start, never
        poison a running cache with inconsistent metadata.
        """
        if not isinstance(snap, dict) or snap.get("version") != SNAPSHOT_VERSION:
            raise SnapshotError(
                f"snapshot version {snap.get('version') if isinstance(snap, dict) else snap!r} "
                f"!= {SNAPSHOT_VERSION}")
        geometry, state = snap.get("geometry", {}), snap.get("state", {})
        if snap.get("checksum") != self._snapshot_checksum(geometry, state):
            raise SnapshotError("snapshot checksum mismatch (corrupt file?)")
        want = {"num_rows": self.num_rows, "dim": self.dim,
                "hot_size": self.hot_size, "cold_slots": self.cold_slots,
                "policy": self.config.policy}
        if geometry != want:
            raise SnapshotError(f"snapshot geometry {geometry} != cache {want}")
        slot_id = np.asarray(state["slot_id"], np.int64)
        slot_rrpv = np.asarray(state["slot_rrpv"], np.int64)
        slot_ts = np.asarray(state["slot_ts"], np.int64)
        if not (slot_id.shape == slot_rrpv.shape == slot_ts.shape
                == (self.cold_slots,)):
            raise SnapshotError("snapshot state arrays have the wrong shape")
        resident = slot_id >= 0
        ids = slot_id[resident]
        if ids.size and (ids.min() < self.hot_size
                         or ids.max() >= self.num_rows
                         or np.unique(ids).size != ids.size):
            raise SnapshotError("snapshot resident ids out of range/duplicated")
        self._slot_id = slot_id
        self._slot_rrpv = slot_rrpv
        self._slot_ts = slot_ts
        self._clock = int(state["clock"])
        self._id_slot = np.full(self.num_rows, -1, np.int64)
        self._id_slot[ids] = np.flatnonzero(resident)
        self._resident = int(ids.size)
        # warm fill: one batched gather from the backing table re-creates
        # the resident cold rows (row data is never part of the snapshot)
        if ids.size:
            self._cold_rows_host[np.flatnonzero(resident)] = self.table[ids]
            self._cold_rows_dirty = True
            self.cold_rows_device()   # eager: restore is once-per-restart
        self.metrics.count("snapshot_restores")
        self.metrics.gauge("restored_resident", int(ids.size))

    def save_snapshot(self, path: str) -> Dict:
        """``snapshot()`` to a JSON file (atomic rename — a crash mid-write
        leaves the previous snapshot intact, not a torn file)."""
        snap = self.snapshot()
        tmp = f"{path}.tmp"
        with open(tmp, "w") as f:
            json.dump(snap, f)
        os.replace(tmp, path)
        return snap

    def load_snapshot(self, path: str) -> bool:
        """Restore from ``path`` if it exists and validates; returns True on
        a warm start, False on a (silent) cold start when the file is
        missing. Everything else — a torn/unparseable file included —
        raises ``SnapshotError``, and the caller decides whether a corrupt
        snapshot is fatal or just a cold start."""
        if not os.path.exists(path):
            return False
        try:
            with open(path) as f:
                snap = json.load(f)
        except (OSError, ValueError) as e:   # JSONDecodeError is a ValueError
            raise SnapshotError(f"unreadable snapshot {path}: {e}") from e
        self.restore(snap)
        return True

    # ------------------------------------------------------------------
    def check_consistency(self) -> None:
        """Invariants the eviction tests lean on (cheap; host metadata only)."""
        res = self._slot_id >= 0
        assert int(res.sum()) <= self.cold_slots
        assert self._resident == int(res.sum()), "resident counter drifted"
        ids = self._slot_id[res]
        assert np.unique(ids).size == ids.size, "duplicate id in cold region"
        assert (self._id_slot[ids] == np.flatnonzero(res)).all()
        back = np.flatnonzero(self._id_slot >= 0)
        assert (self._slot_id[self._id_slot[back]] == back).all()
