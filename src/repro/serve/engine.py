"""Serving engines: cache + scheduler wired to the nn forward paths.

``RecsysServeEngine`` serves MIND candidate-scoring requests: history and
candidate item embeddings are gathered through the GRASP
``EmbeddingCache`` and fed to the shared capsule-routing math
(``nn.recsys.user_interests_from_emb`` / ``score_candidates``).

``GNNServeEngine`` serves node-classification requests: seed nodes are
expanded by the fanout sampler, node features are gathered through the
cache (degree-ordered table => hot prefix = high-degree nodes, the paper's
High Reuse Region), and the GIN forward runs on the padded block graph.

Both engines pad partial batches up to ``max_batch`` *after* the cache
lookup, so jit sees one static shape (no per-batch-size recompiles) while
the cache only ever sees real references.

``LMServeEngine`` serves transformer generate requests (prefill + greedy
decode against a KV cache) behind the same continuous batcher, so the
gateway can put `/v1/generate` on the identical pump/scheduler path as
`/v1/score`.

``lm_loop`` is the transformer prefill+decode driver that used to live in
``launch/serve.py``, kept as the third engine behind the same CLI. Its
final partial batch now computes exactly the remaining ``n`` sequences
(one extra jit specialisation) instead of padding work up to ``batch`` and
misreporting tok/s.

``run_recsys_stream`` drives a full closed-loop run on a zipf request
stream against a virtual clock — the entry point `make serve-smoke` and
the CLI share.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GNNConfig, RecsysConfig
from repro.data.pipeline import zipf_ids
from repro.nn import gnn as gnn_mod
from repro.nn import recsys as recsys_mod
from repro.serve.cache import CacheConfig, EmbeddingCache
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (
    ContinuousBatcher,
    Request,
    SchedulerConfig,
    VirtualClock,
)


def _pad_batch(arrs: List[np.ndarray], width: int) -> np.ndarray:
    """Stack per-request arrays and zero-pad the batch dim to ``width``."""
    x = np.stack(arrs)
    if x.shape[0] < width:
        pad = [(0, width - x.shape[0])] + [(0, 0)] * (x.ndim - 1)
        x = np.pad(x, pad)
    return x


class _EngineBase:
    """Shared continuous-batching pump.

    ``step`` claims a batch, runs ``forward``, and — when the scheduler
    clock is a ``VirtualClock`` — advances it by the measured forward wall
    time (or a deterministic ``service_model(batch_size)``) before
    completion, so virtual-time latency accounting includes service time.
    """

    batcher: ContinuousBatcher
    service_model = None  # Optional[Callable[[int], float]]

    def submit(self, payload: Dict, deadline_s: Optional[float] = None) -> Request:
        return self.batcher.submit(payload, deadline_s)

    def forward(self, payloads: List[Dict]) -> np.ndarray:
        raise NotImplementedError

    def step(self) -> int:
        """Run one continuous-batching iteration; returns batch size."""
        batch = self.batcher.next_batch()
        if not batch:
            return 0
        t0 = time.perf_counter()
        results = self.forward([r.payload for r in batch])
        dt = time.perf_counter() - t0
        clock = self.batcher.clock
        if isinstance(clock, VirtualClock):
            if self.service_model is not None:
                dt = self.service_model(len(batch))
            clock.advance(dt)
        self.batcher.complete(batch, list(results))
        return len(batch)

    def run_until_idle(self) -> None:
        while self.step():
            pass


class RecsysServeEngine(_EngineBase):
    """MIND candidate scoring over the GRASP embedding cache.

    Request payload: ``{"hist": (H,), "hist_mask": (H,), "candidates":
    (C,)}``; result: ``(C,)`` float32 scores. ``params`` must hold a dense
    ``items`` table — the cache becomes the only reader of it.
    """

    def __init__(
        self,
        params: Dict,
        cfg: RecsysConfig,
        cache_config: CacheConfig,
        sched_config: SchedulerConfig,
        metrics: Optional[ServeMetrics] = None,
        clock=time.monotonic,
        service_model=None,
    ) -> None:
        self.cfg = cfg
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.params = {k: v for k, v in params.items() if k != "items"}
        self.cache = EmbeddingCache(
            np.asarray(params["items"]), cache_config, metrics=self.metrics
        )
        self.batcher = ContinuousBatcher(sched_config, clock=clock,
                                         metrics=self.metrics)
        self._width = sched_config.max_batch
        self.service_model = service_model

        def routed(p, e, hist, mask, cand_e):
            interests = recsys_mod.user_interests_from_emb(p, cfg, e, hist, mask)
            return recsys_mod.score_candidates(interests, cand_e)

        self._routed = jax.jit(routed)

    def forward(self, payloads: List[Dict]) -> np.ndarray:
        """Score a list of request payloads; returns (n, C)."""
        n = len(payloads)
        # normalize dtypes so JSON-decoded gateway payloads (int64 lists)
        # hit the same jit specialization as native int32 arrays
        hist = np.stack([p["hist"] for p in payloads]).astype(np.int32)
        cand = np.stack([p["candidates"] for p in payloads]).astype(np.int32)
        mask = np.stack([p["hist_mask"] for p in payloads]).astype(bool)
        e, _ = self.cache.lookup(hist.reshape(-1))
        ce, _ = self.cache.lookup(cand.reshape(-1))
        e = np.asarray(e).reshape(hist.shape + (self.cache.dim,))
        ce = np.asarray(ce).reshape(cand.shape + (self.cache.dim,))
        w = self._width
        scores = self._routed(
            self.params,
            jnp.asarray(_pad_batch(list(e), w)),
            jnp.asarray(_pad_batch(list(hist), w)),
            jnp.asarray(_pad_batch(list(mask), w)),
            jnp.asarray(_pad_batch(list(ce), w)),
        )
        return np.asarray(jax.block_until_ready(scores))[:n]

    def warmup(self, candidates: int) -> None:
        """Trigger the jit compile for the canonical batch shape without
        touching the cache or metrics (gateway startup / benchmarks)."""
        w, h, d = self._width, self.cfg.hist_len, self.cache.dim
        jax.block_until_ready(self._routed(
            self.params,
            jnp.zeros((w, h, d), jnp.float32),
            jnp.zeros((w, h), jnp.int32),
            jnp.zeros((w, h), bool),
            jnp.zeros((w, candidates, d), jnp.float32),
        ))


class GNNServeEngine(_EngineBase):
    """GIN node-classification serving over a cached node-feature table.

    Request payload: ``{"seeds": (S,)}`` with exactly ``seeds_per_req``
    seed node ids; result: ``(S, n_classes)`` logits. The feature table is
    degree-ordered so the cache's pinned prefix covers the hub nodes every
    sampled block touches.
    """

    def __init__(
        self,
        params: Dict,
        cfg: GNNConfig,
        graph,                       # graph.csr.CSR, degree-ordered ids
        features: np.ndarray,        # (N, F) node-feature table
        cache_config: CacheConfig,
        sched_config: SchedulerConfig,
        fanout=(5, 5),
        seeds_per_req: int = 4,
        metrics: Optional[ServeMetrics] = None,
        clock=time.monotonic,
        seed: int = 0,
        service_model=None,
    ) -> None:
        self.cfg = cfg
        self.graph = graph
        self.fanout = tuple(fanout)
        self.seeds_per_req = seeds_per_req
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self.params = params
        self.cache = EmbeddingCache(
            features, cache_config,
            degree=np.asarray(graph.out_degree), metrics=self.metrics,
        )
        self.batcher = ContinuousBatcher(sched_config, clock=clock,
                                         metrics=self.metrics)
        self._width = sched_config.max_batch
        self._rng = np.random.default_rng(seed)
        self.service_model = service_model
        self._apply = jax.jit(
            lambda p, batch: gnn_mod.apply(p, cfg, batch)
        )

    def forward(self, payloads: List[Dict]) -> np.ndarray:
        from repro.graph import sampler

        n = len(payloads)
        seeds = np.concatenate([np.asarray(p["seeds"]) for p in payloads])
        pad_seeds = (self._width - n) * self.seeds_per_req
        if pad_seeds:
            seeds = np.pad(seeds, (0, pad_seeds))  # node 0: hottest, harmless
        blocks = sampler.sample_blocks(self.graph, seeds, self.fanout, self._rng)
        logits = self.forward_blocks(blocks)
        per_req = logits[: n * self.seeds_per_req]
        return per_req.reshape(n, self.seeds_per_req, -1)

    def forward_blocks(self, blocks) -> np.ndarray:
        """Seed-node logits for one sampled block graph (cache-fed gather)."""
        x, _ = self.cache.lookup(blocks.node_ids)
        x = jnp.where(jnp.asarray(blocks.node_mask)[:, None], x, 0.0)
        batch = {
            "x": x,
            "src": jnp.asarray(blocks.src),
            "dst": jnp.asarray(blocks.dst),
            "emask": jnp.asarray(blocks.emask),
        }
        out = jax.block_until_ready(self._apply(self.params, batch))
        return np.asarray(out)[blocks.seeds_local]


class LMServeEngine(_EngineBase):
    """Transformer prefill+decode serving behind the continuous batcher.

    Request payload: ``{"tokens": (<=prefill,) int prompt ids}``; result:
    ``(decode,)`` int32 greedily-decoded ids. Prompts are clipped to the
    last ``prefill`` tokens and left-padded with token 0, so every batch
    hits one static ``(max_batch, prefill)`` jit specialization — the shape
    the gateway pump keeps hot.
    """

    def __init__(
        self,
        arch: str = "minitron-8b",
        smoke: bool = True,
        sched_config: Optional[SchedulerConfig] = None,
        prefill: int = 64,
        decode: int = 32,
        params: Optional[Dict] = None,
        metrics: Optional[ServeMetrics] = None,
        clock=time.monotonic,
        service_model=None,
    ) -> None:
        from repro.configs import base as cfgs
        from repro.nn import transformer as tfm

        cfg = cfgs.get_arch(arch)
        if smoke:
            cfg = cfgs.reduced(cfg)
        self.cfg = cfg
        self.prefill_len = int(prefill)
        self.decode_len = int(decode)
        self.metrics = metrics if metrics is not None else ServeMetrics()
        sched_config = sched_config if sched_config is not None else SchedulerConfig()
        self.batcher = ContinuousBatcher(sched_config, clock=clock,
                                         metrics=self.metrics)
        self._width = sched_config.max_batch
        self.service_model = service_model
        self.params = (params if params is not None
                       else tfm.init(jax.random.PRNGKey(0), cfg))
        max_len = self.prefill_len + self.decode_len
        self._prefill = jax.jit(
            lambda p, t: tfm.prefill(p, cfg, t, max_len=max_len))
        self._decode = jax.jit(lambda p, c, t: tfm.decode_step(p, cfg, c, t))

    def _generate(self, tokens: np.ndarray) -> np.ndarray:
        """(w, prefill) int32 -> (w, decode) int32 greedy continuation."""
        logits, cache = self._prefill(self.params, jnp.asarray(tokens))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(self.decode_len - 1):
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(out[-1])
        return np.stack([np.asarray(t) for t in out], axis=1)

    def forward(self, payloads: List[Dict]) -> np.ndarray:
        n = len(payloads)
        toks = np.zeros((self._width, self.prefill_len), np.int32)
        for i, p in enumerate(payloads):
            t = np.asarray(p["tokens"], np.int32).ravel()[-self.prefill_len:]
            t = np.clip(t, 0, self.cfg.vocab - 1)
            toks[i, self.prefill_len - t.size:] = t
        out = self._generate(toks)
        self.metrics.count("tokens_generated", n * self.decode_len)
        return out[:n]

    def warmup(self) -> None:
        """Compile prefill+decode for the canonical batch shape up front."""
        self._generate(np.zeros((self._width, self.prefill_len), np.int32))


# ---------------------------------------------------------------------------
# LM prefill+decode loop (moved from launch/serve.py; partial batches fixed)
# ---------------------------------------------------------------------------
def lm_loop(arch: str = "starcoder2-7b", smoke: bool = True, requests: int = 16,
            batch: int = 8, prefill: int = 64, decode: int = 32) -> Dict:
    """Batched prefill+decode serving loop for the transformer archs.

    The final batch computes exactly the remaining ``n`` sequences (at the
    cost of one extra jit specialisation) and the report counts only
    tokens actually served — a partial batch no longer inflates tok/s or
    batch latency with padded work.
    """
    from repro.configs import base as cfgs
    from repro.nn import transformer as tfm

    cfg = cfgs.get_arch(arch)
    if smoke:
        cfg = cfgs.reduced(cfg)
    rng = np.random.default_rng(0)
    max_len = prefill + decode

    params = tfm.init(jax.random.PRNGKey(0), cfg)
    prefill_fn = jax.jit(lambda p, t: tfm.prefill(p, cfg, t, max_len=max_len))
    decode_fn = jax.jit(lambda p, c, t: tfm.decode_step(p, cfg, c, t))

    done, toks_served, t0 = 0, 0, time.time()
    lat = []
    while done < requests:
        n = min(batch, requests - done)
        tokens = zipf_ids(rng, (n, prefill), cfg.vocab)
        t1 = time.time()
        logits, cache = prefill_fn(params, jnp.asarray(tokens))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out = [tok]
        for _ in range(decode - 1):
            logits, cache = decode_fn(params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out.append(tok)
        jax.block_until_ready(out[-1])
        lat.append(time.time() - t1)
        done += n
        toks_served += n * decode
    dt = time.time() - t0
    stats = {
        "requests": requests,
        "tokens": toks_served,
        "tok_s": toks_served / dt,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
    }
    print(f"[serve] {requests} requests, {toks_served} tokens in {dt:.2f}s "
          f"({stats['tok_s']:.1f} tok/s); batch latency p50="
          f"{stats['p50_ms']:.0f}ms p99={stats['p99_ms']:.0f}ms")
    return stats


# ---------------------------------------------------------------------------
# Closed-loop zipf stream driver (CLI + `make serve-smoke`)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class StreamConfig:
    requests: int = 256
    qps: float = 2000.0            # offered load (virtual-time arrivals)
    candidates: int = 32
    zipf_a: float = 1.1
    deadline_s: Optional[float] = 0.05
    seed: int = 0


def run_recsys_stream(
    cfg: RecsysConfig,
    cache_config: CacheConfig,
    sched_config: SchedulerConfig,
    stream: StreamConfig,
    params: Optional[Dict] = None,
    service_time_s: Optional[float] = None,
) -> Dict:
    """Drive a zipf-skewed request stream through a fresh engine.

    Arrivals follow a deterministic uniform process at ``stream.qps`` on a
    virtual clock; each batch advances the clock by the *measured* forward
    wall time (or ``service_time_s`` for fully deterministic runs). Returns
    the metrics snapshot, including cache hit rates and latency tails.
    """
    if params is None:
        params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    clock = VirtualClock()
    service_model = (None if service_time_s is None
                     else (lambda n: service_time_s))
    engine = RecsysServeEngine(params, cfg, cache_config, sched_config,
                               clock=clock, service_model=service_model)
    rng = np.random.default_rng(stream.seed)
    arrivals = np.arange(stream.requests) / stream.qps
    payloads = []
    for _ in range(stream.requests):
        hist = zipf_ids(rng, (cfg.hist_len,), cfg.n_items, a=stream.zipf_a)
        cand = zipf_ids(rng, (stream.candidates,), cfg.n_items, a=stream.zipf_a)
        payloads.append({
            "hist": hist,
            "hist_mask": np.ones(cfg.hist_len, bool),
            "candidates": cand,
        })

    i = 0
    while i < stream.requests or engine.batcher.depth:
        while i < stream.requests and arrivals[i] <= clock():
            engine.submit(payloads[i], deadline_s=stream.deadline_s)
            i += 1
        if not engine.batcher.depth:
            clock.advance_to(arrivals[i])
            continue
        engine.step()
    snap = engine.metrics.snapshot()
    snap["config"] = {
        "budget_bytes": cache_config.budget_bytes,
        "hot_fraction": cache_config.hot_fraction,
        "policy": cache_config.policy,
        "hot_size": engine.cache.hot_size,
        "cold_slots": engine.cache.cold_slots,
        "max_batch": sched_config.max_batch,
        "max_queue": sched_config.max_queue,
        "qps": stream.qps,
        "deadline_s": stream.deadline_s,
        "requests": stream.requests,
    }
    return snap
