"""Serving metrics: counters, latency histograms, JSON snapshot emitter.

Everything here is host-side and allocation-free on the hot path: latencies
land in fixed log-spaced buckets (no per-sample storage), counters are a
plain dict. ``snapshot()`` returns the JSON-ready view the benchmarks
consume (``BENCH_serve.json``/``BENCH_gateway.json``); percentile estimates
are read back from the bucket *upper* edges, capped at the exact tracked
``max`` (conservative; worst-case relative error = the sqrt(2) bucket
ratio, ~41%). A percentile that falls in the open-ended overflow bucket
reports the exact ``max`` — there is no finite upper edge to read back.
``max_s``/``mean_s`` are tracked exactly — bound checks should use those,
percentiles are for reporting shape.

``ServeMetrics`` is thread-safe: one instance is shared between the
gateway pump thread, the HTTP handler threads serving ``/metrics``, and
whatever thread drives the cache. A single lock guards the dict/ndarray
mutations; ``LatencyHistogram`` itself stays lock-free (always mutate it
through a ``ServeMetrics``, or from a single thread).
"""
from __future__ import annotations

import json
import threading
from typing import Dict, Optional

import numpy as np

# sqrt(2)-spaced bucket upper edges from 1us to ~91s (55 buckets); the last
# bucket is open-ended. Serving latencies (us..s) sit mid-range.
_N_BUCKETS = 55
_EDGES = 1e-6 * (2.0 ** (np.arange(_N_BUCKETS) / 2.0))


class LatencyHistogram:
    """Fixed log-bucket latency histogram with percentile readback."""

    def __init__(self) -> None:
        self.counts = np.zeros(_N_BUCKETS + 1, np.int64)
        self.total = 0
        self.sum = 0.0
        self.max = 0.0

    def observe(self, seconds: float) -> None:
        i = int(np.searchsorted(_EDGES, seconds))
        self.counts[i] += 1
        self.total += 1
        self.sum += seconds
        self.max = max(self.max, seconds)

    def percentile(self, p: float) -> float:
        """Upper-edge estimate of the p-th percentile (p in [0, 100])."""
        if self.total == 0:
            return 0.0
        rank = np.ceil(self.total * p / 100.0)
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, max(rank, 1)))
        if i >= _N_BUCKETS:
            # open-ended overflow bucket: no finite upper edge to report —
            # fall back to the exact tracked max
            return float(self.max)
        return float(min(_EDGES[i], self.max))

    @property
    def mean(self) -> float:
        return self.sum / self.total if self.total else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "count": int(self.total),
            "mean_s": self.mean,
            "p50_s": self.percentile(50),
            "p99_s": self.percentile(99),
            "max_s": float(self.max),
        }


class ServeMetrics:
    """Counters + named latency histograms for one serving engine.

    Counter names used by the subsystem (all monotonically increasing):
      cache: ``hot_hits`` ``cold_hits`` ``misses`` ``bypassed``
      scheduler: ``admitted`` ``rejected`` ``shed`` ``completed``
      ``failed`` ``batches``
    Histograms: ``queue_wait`` ``service`` ``e2e`` (seconds).
    """

    def __init__(self) -> None:
        self.counters: Dict[str, int] = {}
        self.hists: Dict[str, LatencyHistogram] = {}
        self.gauges: Dict[str, float] = {}
        self._lock = threading.Lock()

    def count(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + int(n)

    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            h = self.hists.get(name)
            if h is None:
                h = self.hists[name] = LatencyHistogram()
            h.observe(seconds)

    def gauge(self, name: str, value: float) -> None:
        with self._lock:
            self.gauges[name] = float(value)

    # -- derived cache figures ------------------------------------------
    @property
    def hit_rate(self) -> float:
        """(hot + cold hits) / all cache references."""
        with self._lock:
            return self._hit_rate_locked()

    def _hit_rate_locked(self) -> float:
        hits = self.counters.get("hot_hits", 0) + self.counters.get("cold_hits", 0)
        total = hits + self.counters.get("misses", 0)
        return hits / total if total else 0.0

    def snapshot(self) -> Dict:
        with self._lock:
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "hit_rate": self._hit_rate_locked(),
                "latency": {k: h.summary() for k, h in self.hists.items()},
            }

    def write_json(self, path: str, extra: Optional[Dict] = None) -> Dict:
        snap = self.snapshot()
        if extra:
            snap.update(extra)
        with open(path, "w") as f:
            json.dump(snap, f, indent=2, sort_keys=True)
        return snap
