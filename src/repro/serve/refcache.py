"""The retained pre-vectorization ``EmbeddingCache.lookup`` — the oracle.

``ReferenceEmbeddingCache`` keeps the original per-miss Python eviction
loop and the original assembly path (a full device→host copy of the cold
block — and, on the no-kernel path, of the padded hot block — per batched
lookup). It exists for two reasons:

  * the randomized equivalence tests replay identical id streams through
    this class and the vectorized ``EmbeddingCache`` and require
    bit-identical outputs, counters, and cold-region metadata — speed
    must never buy different answers;
  * ``benchmarks/perf_smoke.py`` measures the vectorized lookup's rows/s
    against this implementation (the acceptance floor is 3x at batch 256
    on the zipf a=1.1 stream).

Semantics are frozen: do not "improve" this file — its slowness is the
baseline being tracked.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve.cache import EmbeddingCache, LookupStats


class ReferenceEmbeddingCache(EmbeddingCache):
    """``EmbeddingCache`` with the original sequential lookup loop."""

    def lookup(self, ids) -> Tuple[jnp.ndarray, LookupStats]:
        ids = np.asarray(ids, np.int64).reshape(-1)
        b = ids.shape[0]
        if b == 0:
            # aligned with the vectorized short-circuit: no clock tick
            return self._finish(np.zeros((0, self.dim), np.float32),
                                LookupStats())
        if ids.min() < 0 or ids.max() >= self.num_rows:
            raise IndexError("id out of range")
        self._clock += 1
        hot_mask = ids < self.hot_size
        hot_hits = int(hot_mask.sum())

        cold_ids = ids[~hot_mask]
        uniq = np.unique(cold_ids)
        fill_ids, fill_slots = [], []
        if uniq.size:
            resident = self._id_slot[uniq] >= 0
            hit_slots = self._id_slot[uniq[resident]]
            if hit_slots.size:
                self._promote(hit_slots)
            for rid in uniq[~resident]:
                if self.cold_slots == 0:
                    continue
                v = self._evict_one()
                old = self._slot_id[v]
                if old >= 0:
                    self._id_slot[old] = -1
                self._slot_id[v] = rid
                self._id_slot[rid] = v
                self._slot_rrpv[v] = self._insert_rrpv(int(rid))
                self._slot_ts[v] = self._clock
                fill_ids.append(rid)
                fill_slots.append(v)
        if fill_ids:
            rows = jnp.asarray(self.table[np.asarray(fill_ids)])
            self._cold_rows = self._cold_rows.at[np.asarray(fill_slots)].set(rows)

        # --- assemble the batch (original: device round-trips) ---------
        out = np.zeros((b, self.dim), np.float32)
        if self.hot_size > 0 and hot_hits:
            out[hot_mask] = self._gather_hot(ids, hot_mask)
        cold_mask = ~hot_mask
        slots = np.where(cold_mask, self._id_slot[ids], -1)
        served = cold_mask & (slots >= 0)
        if served.any():
            out[served] = np.asarray(self._cold_rows)[slots[served]]
        byp = cold_mask & (slots < 0)
        if byp.any():
            out[byp] = self.table[ids[byp]]

        byp_refs = int(byp.sum())
        misses = len(fill_ids) + byp_refs
        cold_hits = int(cold_mask.sum()) - misses
        stats = LookupStats(hot_hits=hot_hits, cold_hits=cold_hits,
                            misses=misses, bypassed=byp_refs)
        # keep the inherited invariants (incremental counter, host mirror)
        # coherent the way the original full-scan gauge did
        self._resident = int((self._slot_id >= 0).sum())
        if fill_slots:
            self._cold_rows_host[np.asarray(fill_slots)] = \
                self.table[np.asarray(fill_ids)]
        return self._finish(out, stats)

    def _gather_hot(self, ids: np.ndarray, hot_mask: np.ndarray) -> np.ndarray:
        if not self.config.use_kernel:
            # original no-kernel path: full padded hot block off-device
            hit_ids = ids[hot_mask]
            return np.asarray(self._hot_block)[hit_ids, : self.dim]
        return super()._gather_hot(ids, hot_mask)
