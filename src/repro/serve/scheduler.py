"""Continuous-batching request scheduler: admission control, deadlines,
shed-load degradation.

The batcher owns a bounded FIFO of pending requests. ``submit`` applies
admission control (reject immediately once ``max_queue`` is exceeded —
backpressure to the caller instead of unbounded queueing); ``next_batch``
sheds queued requests whose deadline already passed (they would miss it
anyway — executing them only drags down everyone behind), then picks up to
``max_batch`` requests, earliest-deadline-first. Because requests join the
next batch as soon as the previous one retires, a new arrival never waits
for a full batch to drain — continuous batching.

Together the three mechanisms bound the tail: a request that is *served*
waited at most its deadline in queue, so e2e latency is bounded by
``deadline + one batch service time`` no matter how far the offered load
exceeds the budget — overload degrades throughput (sheds), not p99.

Every ``Request`` carries a completion event that is set exactly once,
when it reaches a terminal status (done / shed / rejected / failed) — the
gateway pump's callers block on ``Request.wait`` instead of polling, and a
request can never hang: rejects resolve synchronously in ``submit``, sheds
resolve in ``next_batch``, and a batch whose forward raises is resolved
with a typed error via ``fail``.

The clock is injectable so tests and the smoke benchmark can drive a
virtual timeline deterministically (see ``VirtualClock``).
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, List, Optional

from repro.serve.metrics import ServeMetrics


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_batch: int = 32          # continuous-batch width
    max_queue: int = 256         # admission-control bound on queued requests
    default_deadline_s: Optional[float] = None  # per-request unless overridden


@dataclasses.dataclass
class Request:
    rid: int
    payload: Any
    arrival: float
    deadline: Optional[float]    # absolute time; None = best-effort
    status: str = "queued"       # queued | running | done | shed | rejected | failed
    started: Optional[float] = None
    finished: Optional[float] = None
    result: Any = None
    error: Optional[BaseException] = None   # set when status == "failed"
    # completion event: set exactly once, when the request reaches a
    # terminal status (done/shed/rejected/failed). Gateway callers block on
    # this instead of polling ``status``.
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    TERMINAL = frozenset({"done", "shed", "rejected", "failed"})

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the request resolves; True iff it did in time."""
        return self.done.wait(timeout)

    @property
    def resolved(self) -> bool:
        return self.done.is_set()


class VirtualClock:
    """Deterministic manual clock for tests/benchmarks (seconds)."""

    def __init__(self, start: float = 0.0) -> None:
        self._now = float(start)

    def __call__(self) -> float:
        return self._now

    def advance(self, dt: float) -> float:
        self._now += float(dt)
        return self._now

    def advance_to(self, t: float) -> float:
        self._now = max(self._now, float(t))
        return self._now


class ContinuousBatcher:
    """Thread-safe bounded queue with EDF batching and load shedding."""

    def __init__(
        self,
        config: SchedulerConfig,
        clock: Callable[[], float] = time.monotonic,
        metrics: Optional[ServeMetrics] = None,
    ) -> None:
        self.config = config
        self.clock = clock
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self._pending: List[Request] = []
        self._lock = threading.Lock()
        self._rid = itertools.count()

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._pending)

    def submit(self, payload: Any,
               deadline_s: Optional[float] = None) -> Request:
        """Enqueue one request; sets ``status='rejected'`` when the queue is
        full (the admission-control path — caller sees it synchronously)."""
        now = self.clock()
        rel = deadline_s if deadline_s is not None else self.config.default_deadline_s
        req = Request(
            rid=next(self._rid),
            payload=payload,
            arrival=now,
            deadline=(now + rel) if rel is not None else None,
        )
        with self._lock:
            if len(self._pending) >= self.config.max_queue:
                req.status = "rejected"
                req.done.set()
                self.metrics.count("rejected")
                return req
            self._pending.append(req)
        self.metrics.count("admitted")
        return req

    def next_batch(self) -> List[Request]:
        """Shed expired requests, then claim up to ``max_batch`` (EDF)."""
        now = self.clock()
        shed: List[Request] = []
        with self._lock:
            keep = []
            for r in self._pending:
                if r.deadline is not None and now > r.deadline:
                    r.status = "shed"
                    r.finished = now
                    shed.append(r)
                else:
                    keep.append(r)
            # EDF; ties broken by arrival, then rid (= submission order), so
            # equal-deadline requests batch in a stable FIFO order
            keep.sort(key=lambda r: (r.deadline if r.deadline is not None
                                     else float("inf"), r.arrival, r.rid))
            batch = keep[: self.config.max_batch]
            self._pending = keep[self.config.max_batch:]
            for r in batch:
                r.status = "running"
                r.started = now
        for r in shed:
            r.done.set()
            self.metrics.count("shed")
        for r in batch:
            self.metrics.observe("queue_wait", now - r.arrival)
        if batch:
            self.metrics.count("batches")
            self.metrics.gauge("last_batch_size", len(batch))
        return batch

    def complete(self, batch: List[Request], results: List[Any]) -> None:
        """Attach results and record service/e2e latency for the batch.

        Requests already at a terminal status are skipped: a supervisor may
        have failed out a wedged batch while its (stuck) forward was still
        running — when that forward finally returns, its completion must
        not overwrite the terminal outcome callers already saw.
        """
        now = self.clock()
        fresh: List[Request] = []
        with self._lock:
            for r, res in zip(batch, results):
                if r.status in Request.TERMINAL:
                    continue
                r.status = "done"
                r.finished = now
                r.result = res
                fresh.append(r)
        for r in fresh:
            r.done.set()
            self.metrics.count("completed")
            self.metrics.observe("service", now - (r.started or now))
            self.metrics.observe("e2e", now - r.arrival)

    def fail(self, batch: List[Request], exc: BaseException) -> None:
        """Resolve a claimed batch whose forward raised: callers must never
        hang on a crashed batch, they get a typed error instead. Idempotent
        per request (terminal statuses are left untouched)."""
        now = self.clock()
        fresh: List[Request] = []
        with self._lock:
            for r in batch:
                if r.status in Request.TERMINAL:
                    continue
                r.status = "failed"
                r.finished = now
                r.error = exc
                fresh.append(r)
        for r in fresh:
            r.done.set()
            self.metrics.count("failed")

    def fail_all(self, exc: BaseException) -> List[Request]:
        """Fail every *queued* (unclaimed) request in one step — the
        shutdown last resort for when the claim path itself is broken
        (a ``next_batch`` that raises): callers must unblock even when
        batching can't run. Returns the requests that were failed out."""
        with self._lock:
            pending, self._pending = self._pending, []
        self.fail(pending, exc)
        return pending
