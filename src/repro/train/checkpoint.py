"""Sharded, elastic checkpointing (no orbax in this container — built here).

Layout:  <dir>/step_<N>/
           manifest.msgpack   — pytree structure, shapes, dtypes, mesh info
           arr_<i>.npy        — one file per leaf (host-gathered)
         <dir>/LATEST         — atomic pointer (write tmp + rename)

Elastic restore: arrays are saved device-agnostic (fully gathered) and
re-sharded on load against whatever mesh/sharding the restoring job uses —
restarts may change pod count (elastic scaling) without conversion tools.
Async save runs the serialization on a background thread with a copy-on-
write snapshot (jax arrays are immutable — the references are enough).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten(tree)
    return flat, treedef


def save(ckpt_dir: str, step: int, tree: Any, wait: bool = True) -> threading.Thread:
    """Serialize a pytree of jax/numpy arrays. Returns the writer thread."""
    flat, treedef = _flatten_with_paths(tree)
    # snapshot to host memory synchronously (cheap on CPU; on TPU this is
    # the device->host DMA you must not overlap with the next step's donation)
    host = [np.asarray(x) for x in flat]
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}")
    final = os.path.join(ckpt_dir, f"step_{step}")

    def _write():
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "leaves": [
                {"file": f"arr_{i}.npy", "shape": list(a.shape),
                 "dtype": str(a.dtype)}
                for i, a in enumerate(host)
            ],
        }
        for i, a in enumerate(host):
            np.save(os.path.join(tmp, f"arr_{i}.npy"), a)
        with open(os.path.join(tmp, "manifest.msgpack"), "wb") as f:
            f.write(msgpack.packb(manifest))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        latest_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
        with open(latest_tmp, "w") as f:
            f.write(str(step))
        os.rename(latest_tmp, os.path.join(ckpt_dir, "LATEST"))

    t = threading.Thread(target=_write, daemon=True)
    t.start()
    if wait:
        t.join()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore(ckpt_dir: str, step: Optional[int], like: Any,
            shardings: Any = None) -> Any:
    """Load into the structure of ``like``; re-shard with ``shardings`` when
    given (elastic restore onto a different mesh)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.msgpack"), "rb") as f:
        manifest = msgpack.unpackb(f.read())
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    assert len(flat_like) == len(manifest["leaves"]), (
        f"checkpoint has {len(manifest['leaves'])} leaves, "
        f"restore target has {len(flat_like)}"
    )
    leaves = []
    shard_flat = None
    if shardings is not None:
        shard_flat = treedef.flatten_up_to(shardings)
    for i, (meta, ref) in enumerate(zip(manifest["leaves"], flat_like)):
        a = np.load(os.path.join(d, f"arr_{i}.npy"))
        want = tuple(getattr(ref, "shape", a.shape))
        assert tuple(a.shape) == want, (i, a.shape, want)
        if shard_flat is not None:
            leaves.append(jax.device_put(a, shard_flat[i]))
        else:
            leaves.append(jnp.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def retain(ckpt_dir: str, keep: int = 3):
    """Garbage-collect all but the newest ``keep`` checkpoints."""
    steps = sorted(
        int(n.split("_")[1])
        for n in os.listdir(ckpt_dir)
        if n.startswith("step_")
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"), ignore_errors=True)
