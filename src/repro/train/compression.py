"""Gradient compression for the data-parallel all-reduce.

int8 per-tensor quantization with error feedback (EF-SGD, Karimireddy et
al. 2019): the quantization residual is carried into the next step, so the
compressed optimizer matches the exact one to first order — the tests check
convergence parity on a quadratic. Inside ``shard_map`` the quantized
tensors are what crosses the ICI (4x fewer all-reduce bytes, the
``collective`` roofline term scales accordingly).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_compress(grads, error):
    """(grads + carried error) -> (quantized payloads, new error)."""
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize_int8(target)
        deq = dequantize_int8(q, s)
        return (q, s), target - deq

    out = jax.tree_util.tree_map(
        one, grads, error, is_leaf=lambda x: hasattr(x, "shape")
    )
    flat, treedef = jax.tree_util.tree_flatten(
        out, is_leaf=lambda t: isinstance(t, tuple) and not hasattr(t, "shape")
    )
    payloads = [f[0] for f in flat]
    new_err = [f[1] for f in flat]
    return (
        jax.tree_util.tree_unflatten(treedef, payloads),
        jax.tree_util.tree_unflatten(treedef, new_err),
    )


def init_error(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )


def compressed_psum(grads, error, axis_name: str):
    """All-reduce int8-quantized gradients with error feedback. Call inside
    shard_map over ``axis_name``. Returns (mean grads f32, new error)."""
    (payloads, new_err) = ef_compress(grads, error)

    def reduce_one(qs):
        q, s = qs
        # sum of per-shard dequantized tensors; int8 payload is what moves
        # on the wire (psum of int32-accumulated quantized values + scales)
        acc = jax.lax.psum(q.astype(jnp.int32), axis_name)
        ssum = jax.lax.psum(s, axis_name)
        n = jax.lax.psum(jnp.ones(()), axis_name)
        # each shard used its own scale; communicate scale-weighted values:
        # approximate by mean scale (error feedback absorbs the residual)
        return acc.astype(jnp.float32) * (ssum / n) / n

    mean = jax.tree_util.tree_map(
        reduce_one, payloads,
        is_leaf=lambda t: isinstance(t, tuple) and not hasattr(t, "shape"),
    )
    return mean, new_err
