"""Fault tolerance: restartable step loop, failure injection, straggler
watchdog.

On a real multi-pod deployment each restart re-initializes the jax
distributed runtime with the surviving hosts and restores from the latest
checkpoint; here the same control flow is exercised in-process (the tests
inject failures and assert bit-exact recovery), and the watchdog implements
the detection/decision layer that a cluster scheduler would consume.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.train import checkpoint as ckpt_mod


class InjectedFailure(RuntimeError):
    """Stands in for a TPU worker loss / preemption."""


@dataclasses.dataclass
class FailureInjector:
    """Raises at the configured global steps (once each)."""

    fail_at: tuple = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at and step not in self._fired:
            self._fired.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


@dataclasses.dataclass
class StragglerWatchdog:
    """Per-step timing outlier detection + rebalance decision.

    A step slower than ``threshold`` x the trailing-median flags a
    straggler; ``decide`` reports which logical host to evict/replace and
    how to re-shard (the action a cluster controller would take).
    """

    window: int = 16
    threshold: float = 2.5
    _times: List[float] = dataclasses.field(default_factory=list)
    events: List[Dict] = dataclasses.field(default_factory=list)

    def record(self, step: int, seconds: float,
               per_host_seconds: Optional[np.ndarray] = None) -> bool:
        self._times.append(seconds)
        hist = self._times[-self.window :]
        med = float(np.median(hist))
        is_straggler = len(hist) >= 4 and seconds > self.threshold * med
        if is_straggler:
            host = None
            if per_host_seconds is not None:
                host = int(np.argmax(per_host_seconds))
            self.events.append(
                {"step": step, "seconds": seconds, "median": med, "host": host}
            )
        return is_straggler

    def decide(self) -> Optional[Dict]:
        """Rebalance decision: evict the host implicated in >=3 events."""
        if not self.events:
            return None
        hosts = [e["host"] for e in self.events if e["host"] is not None]
        if not hosts:
            return {"action": "checkpoint_and_restart"}
        vals, counts = np.unique(hosts, return_counts=True)
        worst = int(vals[np.argmax(counts)])
        if counts.max() >= 3:
            return {"action": "evict_host", "host": worst,
                    "then": "elastic_restore"}
        return {"action": "monitor"}


@dataclasses.dataclass
class RunResult:
    state: Dict
    steps_done: int
    restarts: int
    straggler_events: List[Dict]


def run_with_restarts(
    init_state: Callable[[], Dict],
    step_fn: Callable[[Dict, int], Dict],
    num_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 10,
    injector: Optional[FailureInjector] = None,
    watchdog: Optional[StragglerWatchdog] = None,
    max_restarts: int = 10,
) -> RunResult:
    """The production driver loop: step, checkpoint, restart on failure.

    ``step_fn(state, step) -> state`` must be deterministic given (state,
    step) — the data pipeline is seeded per step (data/pipeline.batches), so
    recovery is bit-exact, which the tests assert.
    """
    watchdog = watchdog or StragglerWatchdog()
    restarts = 0
    while True:
        try:
            start = ckpt_mod.latest_step(ckpt_dir)
            if start is None:
                state, start = init_state(), 0
            else:
                state = ckpt_mod.restore(ckpt_dir, start, init_state())
            for step in range(start, num_steps):
                if injector is not None:
                    injector.check(step)
                t0 = time.time()
                state = step_fn(state, step)
                watchdog.record(step, time.time() - t0)
                if (step + 1) % ckpt_every == 0 or step + 1 == num_steps:
                    ckpt_mod.save(ckpt_dir, step + 1, state)
                    ckpt_mod.retain(ckpt_dir, keep=3)
            return RunResult(state, num_steps, restarts, watchdog.events)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
