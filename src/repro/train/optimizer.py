"""Optimizers: SGD(+momentum), AdamW (optional bf16 moments), Adafactor.

optax is not available in this container, so the framework ships its own.
API: ``make(cfg) -> (init_fn, update_fn)`` with
  init_fn(params) -> state
  update_fn(grads, state, params) -> (new_params, new_state)

Adafactor (Shazeer & Stern 2018) factors the second moment of matrices into
row/col statistics — the memory-budget enabler for nemotron-4-340b on
16GB/chip (see DESIGN.md).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"            # sgd | adamw | adafactor
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    moment_dtype: str = "float32"  # "bfloat16" halves Adam state memory
    momentum: float = 0.9          # sgd
    factored_eps: float = 1e-30    # adafactor


def _clip_by_global_norm(grads, max_norm):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale, grads), gnorm


def make(cfg: OptConfig):
    if cfg.name == "sgd":
        return _make_sgd(cfg)
    if cfg.name == "adamw":
        return _make_adamw(cfg)
    if cfg.name == "adafactor":
        return _make_adafactor(cfg)
    raise ValueError(cfg.name)


def _make_sgd(cfg: OptConfig):
    def init(params):
        return {
            "mu": jax.tree_util.tree_map(jnp.zeros_like, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, _ = _clip_by_global_norm(grads, cfg.grad_clip)
        mu = jax.tree_util.tree_map(
            lambda m, g: cfg.momentum * m + g, state["mu"], grads
        )
        new_params = jax.tree_util.tree_map(
            lambda p, m: p - cfg.lr * m, params, mu
        )
        return new_params, {"mu": mu, "step": state["step"] + 1}

    return init, update


def _make_adamw(cfg: OptConfig):
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32

    def init(params):
        z = lambda p: jnp.zeros(p.shape, mdt)
        return {
            "m": jax.tree_util.tree_map(z, params),
            "v": jax.tree_util.tree_map(z, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, _ = _clip_by_global_norm(grads, cfg.grad_clip)
        step = state["step"] + 1
        bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * gf
            v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * gf * gf
            mhat = m32 / bc1
            vhat = v32 / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p
            return p - cfg.lr * delta, m32.astype(mdt), v32.astype(mdt)

        out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree_util.tree_map(
            lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple)
        )
        m = jax.tree_util.tree_map(
            lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple)
        )
        v = jax.tree_util.tree_map(
            lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple)
        )
        return new_params, {"m": m, "v": v, "step": step}

    return init, update


def _make_adafactor(cfg: OptConfig):
    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params):
        def z(p):
            if _factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree_util.tree_map(z, params,
                                        is_leaf=lambda x: hasattr(x, "shape")),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        grads, _ = _clip_by_global_norm(grads, cfg.grad_clip)
        step = state["step"] + 1
        decay = 1.0 - step.astype(jnp.float32) ** -0.8

        def upd(p, g, v):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + cfg.factored_eps
            if _factored(p):
                vr = decay * v["vr"] + (1 - decay) * g2.mean(axis=-1)
                vc = decay * v["vc"] + (1 - decay) * g2.mean(axis=-2)
                denom = (
                    vr[..., :, None]
                    * vc[..., None, :]
                    / jnp.maximum(vr.mean(axis=-1)[..., None, None], 1e-30)
                )
                pre = gf * jax.lax.rsqrt(denom + cfg.factored_eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv_ = decay * v["v"] + (1 - decay) * g2
                pre = gf * jax.lax.rsqrt(nv_ + cfg.factored_eps)
                nv = {"v": nv_}
            # update clipping (RMS <= 1) per Adafactor
            rms = jnp.sqrt(jnp.mean(pre * pre) + 1e-30)
            pre = pre / jnp.maximum(1.0, rms)
            new_p = p - cfg.lr * (pre + cfg.weight_decay * p)
            return new_p, nv

        flat, tree = jax.tree_util.tree_flatten(params)
        gflat = tree.flatten_up_to(grads)
        vflat = jax.tree_util.tree_leaves(
            state["v"], is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x)
        )
        outs = [upd(p, g, v) for p, g, v in zip(flat, gflat, vflat)]
        new_params = jax.tree_util.tree_unflatten(tree, [o[0] for o in outs])
        new_v = jax.tree_util.tree_unflatten(tree, [o[1] for o in outs])
        return new_params, {"v": new_v, "step": step}

    return init, update


def for_arch(arch_cfg, lr: float = 1e-3) -> OptConfig:
    name = getattr(arch_cfg, "optimizer", "adamw")
    # bf16 moments for multi-billion-param models (memory budget, DESIGN.md)
    big = getattr(arch_cfg, "param_count", lambda: 0)() > 8e9
    return OptConfig(name=name, lr=lr,
                     moment_dtype="bfloat16" if big else "float32")
