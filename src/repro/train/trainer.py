"""Training driver: microbatched/gradient-accumulated step, remat policy,
metrics, checkpoint + fault-tolerant loop integration.

``Trainer`` is the single-process engine used by examples/ and the
end-to-end test; on the production mesh the same step is jitted with the
cell shardings from launch/steps.py.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train import checkpoint as ckpt_mod
from repro.train import ft as ft_mod
from repro.train import optimizer as opt_mod


@dataclasses.dataclass
class TrainerConfig:
    num_steps: int = 100
    microbatches: int = 1          # gradient accumulation
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    donate: bool = True


class Trainer:
    def __init__(
        self,
        loss_fn: Callable,          # (params, batch) -> scalar loss
        init_params: Callable[[], Any],
        opt_cfg: opt_mod.OptConfig,
        tcfg: TrainerConfig,
        mesh=None,
        in_shardings=None,
        out_shardings=None,
    ):
        self.loss_fn = loss_fn
        self.init_params = init_params
        self.opt_init, self.opt_update = opt_mod.make(opt_cfg)
        self.tcfg = tcfg
        self.mesh = mesh
        self.watchdog = ft_mod.StragglerWatchdog()
        self.history: list = []

        def step(params, opt_state, batch):
            if tcfg.microbatches > 1:
                # gradient accumulation over leading-dim splits
                def micro(g_acc, mb):
                    loss, g = jax.value_and_grad(self.loss_fn)(params, mb)
                    return jax.tree_util.tree_map(jnp.add, g_acc, g), loss

                splits = jax.tree_util.tree_map(
                    lambda x: x.reshape((tcfg.microbatches, -1) + x.shape[1:]),
                    batch,
                )
                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                grads, losses = jax.lax.scan(
                    lambda acc, mb: micro(acc, mb), zeros, splits
                )
                grads = jax.tree_util.tree_map(
                    lambda g: g / tcfg.microbatches, grads
                )
                loss = losses.mean()
            else:
                loss, grads = jax.value_and_grad(self.loss_fn)(params, batch)
            new_params, new_opt = self.opt_update(grads, opt_state, params)
            gnorm = jnp.sqrt(
                sum(
                    jnp.sum(g.astype(jnp.float32) ** 2)
                    for g in jax.tree_util.tree_leaves(grads)
                )
            )
            return new_params, new_opt, {"loss": loss, "gnorm": gnorm}

        kwargs = {}
        if in_shardings is not None:
            kwargs.update(in_shardings=in_shardings, out_shardings=out_shardings)
        donate = (0, 1) if tcfg.donate else ()
        self._step = jax.jit(step, donate_argnums=donate, **kwargs)

    def init_state(self) -> Dict:
        params = self.init_params()
        return {"params": params, "opt": self.opt_init(params)}

    def fit(self, batch_fn: Callable[[int], Dict],
            injector: Optional[ft_mod.FailureInjector] = None) -> Dict:
        """Run with the fault-tolerant restart loop when ckpt_dir is set.

        ``batch_fn(step) -> batch`` must be deterministic in ``step`` (the
        pipeline seeds per step) so restarts replay identical data."""
        tcfg = self.tcfg

        def step_fn(state, step):
            b = jax.tree_util.tree_map(jnp.asarray, batch_fn(step))
            params, opt, metrics = self._step(state["params"], state["opt"], b)
            if (step + 1) % tcfg.log_every == 0 or step == 0:
                m = {k: float(v) for k, v in metrics.items()}
                self.history.append({"step": step + 1, **m})
                print(f"[train] step {step+1:5d} "
                      + " ".join(f"{k}={v:.4f}" for k, v in m.items()))
            return {"params": params, "opt": opt}

        if tcfg.ckpt_dir:
            res = ft_mod.run_with_restarts(
                self.init_state, step_fn, tcfg.num_steps, tcfg.ckpt_dir,
                ckpt_every=tcfg.ckpt_every, injector=injector,
                watchdog=self.watchdog,
            )
            return res.state
        state = self.init_state()
        for s in range(tcfg.num_steps):
            state = step_fn(state, s)
        return state
