"""Minimal stand-in for the subset of `hypothesis` this suite uses.

The real dependency is declared in requirements-dev.txt; this shim exists
because the test container has no package index. tests/conftest.py puts it
on sys.path only when `import hypothesis` fails, so installing the real
package transparently takes over (shrinking, the full strategy library,
the database, ...).

Supported: @given with positional or keyword strategies, @settings
(max_examples, deadline ignored), strategies.integers / sampled_from /
booleans / floats. Draws come from a PRNG seeded on the test's qualified
name, so runs are deterministic; boundary values are drawn first.
"""
import functools
import inspect
import random

from . import strategies

__all__ = ["given", "settings", "strategies"]
__version__ = "0.0.0.shim"

_DEFAULT_MAX_EXAMPLES = 50


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._shim_settings = {"max_examples": int(max_examples)}
        return fn

    return deco


def given(*arg_strategies, **kw_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_shim_settings", None)
                   or getattr(fn, "_shim_settings", None)
                   or {"max_examples": _DEFAULT_MAX_EXAMPLES})
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            for i in range(cfg["max_examples"]):
                drawn = [s.example(rng, first=(i == 0))
                         for s in arg_strategies]
                drawn_kw = {k: s.example(rng, first=(i == 0))
                            for k, s in kw_strategies.items()}
                fn(*args, *drawn, **drawn_kw, **kwargs)

        # Hide the strategy-filled parameters from pytest's fixture
        # resolution: positional strategies fill the RIGHTMOST params
        # (hypothesis semantics), keyword strategies fill by name.
        sig = inspect.signature(fn)
        names = list(sig.parameters)
        filled = set(kw_strategies)
        if arg_strategies:
            filled.update(names[len(names) - len(arg_strategies):])
        wrapper.__signature__ = sig.replace(
            parameters=[p for n, p in sig.parameters.items()
                        if n not in filled])
        # inspect/pytest would unwrap back to fn (full signature) otherwise
        del wrapper.__wrapped__
        return wrapper

    return deco
