"""Strategy objects for the hypothesis shim: each has .example(rng, first).

The first draw of a run returns a boundary value (hypothesis probes edges
aggressively; cheap imitation, deterministic given the rng).
"""
import math


class SearchStrategy:
    def __init__(self, draw, boundary=None):
        self._draw = draw
        self._boundary = boundary

    def example(self, rng, first=False):
        if first and self._boundary is not None:
            return self._boundary
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)),
                              None if self._boundary is None
                              else f(self._boundary))


def integers(min_value, max_value):
    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return min_value
        if r < 0.10:
            return max_value
        return rng.randint(min_value, max_value)

    return SearchStrategy(draw, boundary=min_value)


def sampled_from(elements):
    elems = list(elements)
    if not elems:
        raise ValueError("sampled_from requires a non-empty collection")
    return SearchStrategy(lambda rng: elems[rng.randrange(len(elems))],
                          boundary=elems[0])


def booleans():
    return SearchStrategy(lambda rng: bool(rng.getrandbits(1)),
                          boundary=False)


def floats(min_value=0.0, max_value=1.0, **_kw):
    lo, hi = float(min_value), float(max_value)
    if not (math.isfinite(lo) and math.isfinite(hi)):
        raise ValueError("shim floats() needs finite bounds")
    return SearchStrategy(lambda rng: rng.uniform(lo, hi), boundary=lo)
