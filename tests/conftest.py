# NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
# single real CPU device; only launch/dryrun.py (its own process) forces 512
# placeholder devices.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Fall back to the vendored hypothesis shim only when the real package is
# missing (this container has no index; requirements-dev.txt declares it).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_shims"))

import repro.dist  # noqa: E402,F401  installs jax.set_mesh/jax.shard_map aliases
