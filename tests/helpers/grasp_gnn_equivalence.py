import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.dist import collectives as coll
from repro.nn import gnn as gnn_mod
from repro.configs import base as cfgs
from repro.core.reorder import reorder_ranks
from repro.graph import generate
from repro.graph.csr import apply_reorder, CSR
from repro.train import optimizer as opt_mod
from repro.launch.mesh import make_debug_mesh

mesh = make_debug_mesh(2, 2)   # P = 4
g = generate.rmat(8, 6, seed=0)
g = apply_reorder(g, reorder_ranks(g, "dbg"))
P_DEV = 4
spec = coll.partition_spec_for(g.num_nodes, g.num_edges, P_DEV,
                               hot=64, pub_frac=1.0, edge_slack=3.0)
print("spec:", spec)
part = coll.grasp_partition(g, spec)
print("dropped:", part["dropped"], "/", part["total_edges"])
assert part["dropped"] == 0

cfg = cfgs.GNNConfig(name="t", kind="gin", n_layers=2, d_hidden=16)
d_feat, n_classes = 8, 5
rng = np.random.default_rng(0)
params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=d_feat)
opt_init, opt_update = opt_mod.make(opt_mod.OptConfig(lr=1e-3))
opt_state = opt_init(params)

n_pad = spec.num_nodes
x = rng.standard_normal((n_pad, d_feat)).astype(np.float32)
labels = rng.integers(0, n_classes, n_pad).astype(np.int32)

# build grasp batch
x_hot = x[:spec.hot]
x_cold = x[spec.hot:].reshape(P_DEV, spec.cold_per_dev, d_feat)
lab_own = np.zeros((P_DEV, spec.n_own), np.int32)
for p in range(P_DEV):
    hot_ids = np.arange(p*spec.hot_per_dev, (p+1)*spec.hot_per_dev)
    cold_ids = spec.hot + np.arange(p*spec.cold_per_dev, (p+1)*spec.cold_per_dev)
    lab_own[p] = labels[np.concatenate([hot_ids, cold_ids])]
batch = dict(x_hot=jnp.asarray(x_hot), x_cold=jnp.asarray(x_cold),
             esrc=jnp.asarray(part["esrc"]), edst=jnp.asarray(part["edst"]),
             emask=jnp.asarray(part["emask"]), pub=jnp.asarray(part["pub"]),
             labels=jnp.asarray(lab_own))

step, specs = coll.make_grasp_gin_step(spec, cfg, d_feat, n_classes, mesh, opt_update)
with jax.set_mesh(mesh):
    new_p, new_o, metrics = jax.jit(step)(params, opt_state, batch)
loss_grasp = float(metrics["loss"])

# reference: unpartitioned gin on padded graph (same weights)
from repro.launch.steps import _gnn_loss
ref_batch = {
    "x": jnp.asarray(x),
    "src": jnp.asarray(g.indices.astype(np.int32)),
    "dst": jnp.asarray(g.dst_ids().astype(np.int32)),
    "emask": jnp.ones(g.num_edges, bool),
    "labels": jnp.asarray(labels),
}
logits = gnn_mod.apply(params, cfg, ref_batch)
logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
ll = jnp.take_along_axis(logp, ref_batch["labels"][:, None], axis=-1)[:, 0]
loss_ref = float(-ll.mean())
print(f"grasp loss={loss_grasp:.6f} ref loss={loss_ref:.6f} diff={abs(loss_grasp-loss_ref):.2e}")
assert abs(loss_grasp - loss_ref) < 1e-4
print("GRASP GNN exchange matches unpartitioned reference")
