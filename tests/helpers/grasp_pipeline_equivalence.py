"""Bit-exactness of the pipelined GRASP exchange (overlap=True, default)
vs the sequential reference (overlap=False): identical loss AND params at
every step over >= 3 layers and >= 5 optimizer steps on the simulated
8-device mesh. Run standalone (own process — XLA's host device count must
be set before jax initialises); wired into scripts/verify.sh.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from repro.dist import collectives as coll
from repro.nn import gnn as gnn_mod
from repro.configs import base as cfgs
from repro.core.reorder import reorder_ranks
from repro.graph import generate
from repro.graph.csr import apply_reorder
from repro.train import optimizer as opt_mod
from repro.launch.mesh import make_debug_mesh

P_DEV, N_LAYERS, N_STEPS = 8, 3, 5
mesh = make_debug_mesh(2, 4)   # P = 8
g = generate.rmat(9, 7, seed=1)
g = apply_reorder(g, reorder_ranks(g, "dbg"))
spec = coll.partition_spec_for(g.num_nodes, g.num_edges, P_DEV,
                               hot=128, pub_frac=1.0, edge_slack=3.0)
part = coll.grasp_partition(g, spec)
assert part["dropped"] == 0

cfg = cfgs.GNNConfig(name="pipe", kind="gin", n_layers=N_LAYERS, d_hidden=24)
d_feat, n_classes = 12, 5
rng = np.random.default_rng(0)
params0 = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=d_feat)
opt_init, opt_update = opt_mod.make(opt_mod.OptConfig(lr=1e-3))

x = rng.standard_normal((spec.num_nodes, d_feat)).astype(np.float32)
labels = rng.integers(0, n_classes, spec.num_nodes).astype(np.int32)
lab_own = np.zeros((P_DEV, spec.n_own), np.int32)
for p in range(P_DEV):
    hot_ids = np.arange(p * spec.hot_per_dev, (p + 1) * spec.hot_per_dev)
    cold_ids = spec.hot + np.arange(p * spec.cold_per_dev,
                                    (p + 1) * spec.cold_per_dev)
    lab_own[p] = labels[np.concatenate([hot_ids, cold_ids])]
batch = dict(x_hot=jnp.asarray(x[:spec.hot]),
             x_cold=jnp.asarray(x[spec.hot:].reshape(P_DEV, spec.cold_per_dev,
                                                     d_feat)),
             esrc=jnp.asarray(part["esrc"]), edst=jnp.asarray(part["edst"]),
             emask=jnp.asarray(part["emask"]), pub=jnp.asarray(part["pub"]),
             labels=jnp.asarray(lab_own))

traj, finals = {}, {}
for name, overlap in (("sequential", False), ("pipelined", True)):
    step, _ = coll.make_grasp_gin_step(spec, cfg, d_feat, n_classes, mesh,
                                       opt_update, overlap=overlap)
    p_, o_ = params0, opt_init(params0)
    losses = []
    with jax.set_mesh(mesh):
        jstep = jax.jit(step)
        for _ in range(N_STEPS):
            p_, o_, m = jstep(p_, o_, batch)
            losses.append(float(m["loss"]))
    traj[name] = losses
    finals[name] = p_
    print(f"{name:10s} losses: {[f'{v:.6f}' for v in losses]}")

assert traj["sequential"] == traj["pipelined"], \
    f"loss trajectories diverged: {traj}"
leaves_s = jax.tree_util.tree_leaves(finals["sequential"])
leaves_p = jax.tree_util.tree_leaves(finals["pipelined"])
assert len(leaves_s) == len(leaves_p)
for i, (a, b) in enumerate(zip(leaves_s, leaves_p)):
    assert bool((a == b).all()), f"param leaf {i} not bit-equal"
print(f"pipelined GRASP step bit-exact vs sequential over "
      f"{N_LAYERS} layers x {N_STEPS} steps on {P_DEV} devices")
