"""Graph applications vs independent references (networkx / hand Brandes)."""
import collections

import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest

from repro import apps
from repro.graph import generate
from repro.graph.csr import transpose
from repro.graph.generate import add_uniform_weights


@pytest.fixture(scope="module")
def g():
    return generate.rmat(9, 8, seed=3)


@pytest.fixture(scope="module")
def nxg(g):
    G = nx.DiGraph()
    G.add_nodes_from(range(g.num_nodes))
    G.add_edges_from(zip(g.indices.tolist(), g.dst_ids().tolist()))
    return G


def test_pagerank_matches_networkx(g, nxg):
    pr = np.asarray(apps.pagerank(g.device(), tol=1e-9, max_iters=200))
    ref = nx.pagerank(nxg, alpha=0.85, tol=1e-10)
    ref = np.array([ref[i] for i in range(g.num_nodes)])
    assert pr.sum() == pytest.approx(1.0, abs=1e-3)
    assert np.abs(pr - ref).max() < 1e-4


def test_pagerank_delta_approximates_pagerank(g):
    pr = np.asarray(apps.pagerank(g.device(), tol=1e-9, max_iters=200))
    prd = np.asarray(apps.pagerank_delta(g.device(), epsilon=1e-9, max_iters=300))
    # PRD is an approximation (no dangling redistribution): rankings agree
    k = 50
    top_pr = set(np.argsort(-pr)[:k].tolist())
    top_prd = set(np.argsort(-prd)[:k].tolist())
    assert len(top_pr & top_prd) >= int(0.8 * k)


def test_sssp_matches_dijkstra(g):
    gw = add_uniform_weights(g, seed=1)
    gout = transpose(gw)
    dist = np.asarray(apps.sssp(gout.device(), 0))
    GW = nx.DiGraph()
    GW.add_nodes_from(range(g.num_nodes))
    for s, d, w in zip(gw.indices.tolist(), gw.dst_ids().tolist(),
                       gw.weights.tolist()):
        GW.add_edge(s, d, weight=w)
    ref = nx.single_source_dijkstra_path_length(GW, 0)
    for v, rd in ref.items():
        assert dist[v] == pytest.approx(rd, abs=1e-3)
    for v in range(g.num_nodes):
        if v not in ref:
            assert np.isinf(dist[v])


def _brandes_ref(G, s):
    S, P = [], collections.defaultdict(list)
    sigma = collections.defaultdict(float)
    dist = {s: 0}
    sigma[s] = 1.0
    Q = collections.deque([s])
    while Q:
        v = Q.popleft()
        S.append(v)
        for w in G.successors(v):
            if w not in dist:
                dist[w] = dist[v] + 1
                Q.append(w)
            if dist[w] == dist[v] + 1:
                sigma[w] += sigma[v]
                P[w].append(v)
    delta = collections.defaultdict(float)
    while S:
        w = S.pop()
        for v in P[w]:
            delta[v] += sigma[v] / sigma[w] * (1 + delta[w])
    return delta, sigma, dist


def test_bc_matches_brandes(g, nxg):
    delta, sigma, level = apps.bc_single_source(transpose(g).device(), 0)
    delta, sigma, level = map(np.asarray, (delta, sigma, level))
    dref, sgref, distref = _brandes_ref(nxg, 0)
    for v, d in distref.items():
        assert level[v] == d
        assert sigma[v] == pytest.approx(sgref[v], rel=1e-4)
    for v, dd in dref.items():
        assert delta[v] == pytest.approx(dd, rel=1e-2, abs=1e-2)


def test_radii_lower_bounds_eccentricity(g, nxg):
    roots = jnp.arange(8, dtype=jnp.int32)
    radii, mask = apps.radii_estimate(g.device(), roots)
    radii = np.asarray(radii)
    # radii estimates are bounded by the largest BFS depth from any root
    assert radii.min() >= 0
    und = nxg.reverse()  # pull over in-edges = forward BFS on reversed graph
    for r in range(8):
        lengths = nx.single_source_shortest_path_length(und, r)
        max_depth = max(lengths.values())
        assert radii.max() <= max_depth + 8  # loose sanity bound


def test_engine_pull_push_consistency(g):
    """Pull over in-CSR == push over out-CSR for a linear reduction."""
    from repro.apps.engine import edge_map_pull, edge_map_push, sum_reduce

    prop = jnp.asarray(np.random.default_rng(0).random(g.num_nodes),
                       dtype=jnp.float32)
    pull = edge_map_pull(g.device(), prop, reduce_fn=sum_reduce)
    push = edge_map_push(
        transpose(g).device(), prop, reduce_fn=sum_reduce, identity=0.0
    )
    assert np.allclose(np.asarray(pull), np.asarray(push), atol=1e-3)
