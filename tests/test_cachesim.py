"""LLC simulator + policies: unit semantics, paper invariants, and
hypothesis properties (OPT optimality, GRASP==RRIP with hints disabled)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cachesim
from repro.core.cachesim import Trace, finalize_trace, simulate
from repro.core.policies import POLICIES
from repro.core.regions import DEFAULT
from repro.graph import datasets, traces
from repro.graph.csr import apply_reorder
from repro.core.reorder import reorder_ranks

LLC = 16 * 1024  # 16 sets x 16 ways x 64B


def mk_trace(lines, hints=None, pcs=None):
    lines = np.asarray(lines, dtype=np.int64)
    hints = np.full(lines.shape, 3, np.int8) if hints is None else np.asarray(hints, np.int8)
    pcs = np.zeros(lines.shape, np.int32) if pcs is None else np.asarray(pcs, np.int32)
    return finalize_trace(lines, hints, pcs)


def test_lru_semantics_tiny():
    # 1 set x 16 ways effectively: lines all map to set 0 with stride S
    # repeat 16 lines -> all hits on second pass; 17 lines -> all misses (LRU)
    s = 16  # num_sets for LLC/16 ways
    fit = np.tile(np.arange(16) * s, 2)
    r = simulate(mk_trace(fit), "lru", LLC)
    assert r.hits == 16
    over = np.tile(np.arange(17) * s, 2)
    r = simulate(mk_trace(over), "lru", LLC)
    assert r.hits == 0  # classic LRU thrash


def test_opt_beats_lru_on_thrash():
    s = 16
    over = np.tile(np.arange(17) * s, 4)
    lru = simulate(mk_trace(over), "lru", LLC)
    opt = simulate(mk_trace(over), "opt", LLC)
    assert opt.hits > lru.hits


def test_next_use_computation():
    nxt = cachesim.compute_next_use(np.array([5, 7, 5, 7, 5]))
    assert nxt[0] == 2 and nxt[1] == 3 and nxt[2] == 4
    assert nxt[3] > 4 and nxt[4] > 4  # INF


def test_grasp_equals_rrip_when_hints_default():
    """Paper Sec. III-A: ABRs not set => Default hints => GRASP degenerates
    to the base RRIP scheme exactly."""
    rng = np.random.default_rng(0)
    lines = rng.zipf(1.3, 20_000) % 4096
    t = mk_trace(lines)  # all-Default hints
    a = simulate(t, "rrip", LLC)
    b = simulate(t, "grasp", LLC)
    assert a.hits == b.hits


def test_grasp_beats_rrip_on_skewed_reordered_trace():
    g = datasets.load("lj", scale=13)
    g2 = apply_reorder(g, reorder_ranks(g, "dbg"))
    llc = datasets.scaled_llc_bytes("lj", g2, elem_bytes=16)
    tr, _ = traces.generate_trace(g2, "pr", llc, max_records=400_000)
    rrip = simulate(tr, "rrip", llc)
    grasp = simulate(tr, "grasp", llc)
    opt = simulate(tr, "opt", llc)
    assert grasp.misses < rrip.misses          # paper Fig. 5
    assert opt.misses < grasp.misses           # Belady bound (Fig. 11)


def test_hint_accounting_sums():
    rng = np.random.default_rng(1)
    lines = rng.integers(0, 2048, 5000)
    hints = rng.integers(0, 4, 5000).astype(np.int8)
    t = mk_trace(lines, hints)
    r = simulate(t, "grasp", LLC)
    assert r.hits == r.hits_by_hint.sum()
    assert r.accesses == r.accesses_by_hint.sum()
    assert np.all(r.misses_by_hint() >= 0)


def test_pin100_protects_high_region():
    """XMem-style pinning: a High-hinted line, once pinned, survives
    arbitrary thrash (paper Sec. II-F pinning semantics)."""
    s = 16
    hot = 0
    thrash = (1 + np.arange(64)) * s  # same set as hot, 64 distinct lines
    lines = np.concatenate([[hot], thrash, [hot]])
    hints = np.full(lines.shape, 2, np.int8)
    hints[0] = hints[-1] = 0  # High-Reuse on the hot line
    r = simulate(mk_trace(lines, hints), "pin_100", LLC)
    assert r.hits_by_hint[0] == 1  # the re-access hits despite thrash


def test_rrip_inserts_protect_against_scan():
    """RRIP's distant insertion keeps a reused line resident through a
    one-shot scan (the thrash-resistance LRU lacks)."""
    s = 16
    reused = np.arange(8) * s
    scan = (100 + np.arange(64)) * s
    lines = np.concatenate([np.tile(reused, 3), scan, reused])
    rrip = simulate(mk_trace(lines), "rrip", LLC)
    lru = simulate(mk_trace(lines), "lru", LLC)
    assert rrip.hits >= lru.hits


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_opt_is_optimal_property(seed):
    """Belady OPT (with bypass) never loses to any online policy."""
    rng = np.random.default_rng(seed)
    lines = rng.zipf(1.5, 3000) % 512
    t = mk_trace(lines)
    llc = 4 * 1024  # 4 sets x 16 ways
    opt = simulate(t, "opt", llc)
    for pol in ("lru", "rrip", "grasp", "ship_mem", "leeway"):
        r = simulate(t, pol, llc)
        assert opt.hits >= r.hits, (pol, opt.hits, r.hits)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_sim_invariants_all_policies(seed):
    rng = np.random.default_rng(seed)
    lines = rng.integers(0, 1024, 2000)
    hints = rng.integers(0, 4, 2000).astype(np.int8)
    pcs = rng.integers(0, 4, 2000).astype(np.int32)
    t = mk_trace(lines, hints, pcs)
    for pol in POLICIES:
        r = simulate(t, pol, 4 * 1024)
        assert 0 <= r.hits <= r.accesses, pol
        # re-access of the same line immediately is always a hit (all
        # policies install on miss except OPT's bypass)
        if pol != "opt":
            rep = mk_trace(np.repeat(lines[:500], 2))
            rr = simulate(rep, pol, 4 * 1024)
            assert rr.hits >= 500, pol


def test_perfmodel_speedup_direction():
    pm = cachesim.PerfModel()
    base = cachesim.SimResult("rrip", 1000, 500, np.zeros(4), np.zeros(4))
    better = cachesim.SimResult("grasp", 1000, 550, np.zeros(4), np.zeros(4))
    assert pm.speedup(base, better) > 1.0
    assert pm.speedup(base, base) == pytest.approx(1.0)
