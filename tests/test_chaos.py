"""repro.chaos + the gateway resilience layers it exists to validate.

Unit-level companions to ``benchmarks/chaos_smoke.py``: seeded injection
determinism, supervisor recovery from the silent-pump-death failure mode,
circuit-breaker state machine on a fake clock, GRASP cache
snapshot/restore (incl. corruption/mismatch rejection), idempotency-key
dedupe over real loopback sockets, and the client's defensive
Retry-After parse. Everything here is jax-light: the serving stack is
exercised with stub engines; only the cache tests touch device arrays.
"""
import json
import os
import threading
import time
import types
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.chaos import ChaosClient, ChaosEngine, FaultSchedule, FaultSpec
from repro.chaos.inject import InjectedFault
from repro.gateway import (
    CircuitBreaker,
    EnginePump,
    Failed,
    GatewayClient,
    GatewayServer,
    IdempotencyCache,
    PumpSupervisor,
    Timeout,
    Unavailable,
)
from repro.gateway.client import _parse_retry_after
from repro.serve.cache import CacheConfig, EmbeddingCache, SnapshotError
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import ContinuousBatcher, SchedulerConfig

from tests.test_gateway import EchoEngine, _scripted_server

FAST_SUP = dict(check_interval_s=0.002, backoff_s=0.002, backoff_cap_s=0.01)


class ScriptedSchedule(FaultSchedule):
    """Fires exactly the given ``(kind, index)`` pairs — unit tests want
    surgical injection, not probabilistic rates."""

    def __init__(self, fire):
        super().__init__(FaultSpec())
        self._fire = frozenset(fire)

    def decide(self, kind, index):
        if (kind, index) in self._fire:
            self.log.record(kind, index)
            return True
        return False


class ScoreStubEngine:
    """jax-free engine that satisfies the /v1/score route surface and
    counts forward executions (the double-execution detector)."""

    def __init__(self, sched=None):
        self.metrics = ServeMetrics()
        self.batcher = ContinuousBatcher(
            sched or SchedulerConfig(max_batch=4, max_queue=16),
            metrics=self.metrics)
        self.cfg = types.SimpleNamespace(n_items=100, hist_len=4)
        self.executions = 0

    def forward(self, payloads):
        self.executions += len(payloads)
        return [np.arange(len(p["candidates"]), dtype=np.float32)
                for p in payloads]


# ---------------------------------------------------------------------------
# seeded injection: determinism + wrappers
# ---------------------------------------------------------------------------
def test_fault_decisions_are_pure_functions_of_seed():
    spec = FaultSpec(seed=123, forward_error_rate=0.3, pump_crash_rate=0.1)
    a, b = FaultSchedule(spec), FaultSchedule(spec)
    seq_a = [a.decide("forward_error", i) for i in range(200)]
    seq_b = [b.decide("forward_error", i) for i in range(200)]
    assert seq_a == seq_b and any(seq_a) and not all(seq_a)
    assert a.log.entries() == b.log.entries()
    # the log holds exactly the fired indices
    assert a.log.count("forward_error") == sum(seq_a)
    # kinds draw independent streams: same indices, different decisions
    seq_crash = [a.decide("pump_crash", i) for i in range(200)]
    assert seq_crash != seq_a
    # a different seed moves the fired set
    c = FaultSchedule(FaultSpec(seed=124, forward_error_rate=0.3))
    assert [c.decide("forward_error", i) for i in range(200)] != seq_a


def test_fault_rate_edges():
    always = FaultSchedule(FaultSpec(forward_error_rate=1.0))
    never = FaultSchedule(FaultSpec(forward_error_rate=0.0))
    assert all(always.decide("forward_error", i) for i in range(8))
    assert not any(never.decide("forward_error", i) for i in range(8))
    assert never.log.entries() == []


def test_injection_log_order_and_summary():
    sched = ScriptedSchedule([("conn_reset", 3), ("forward_error", 1),
                              ("forward_error", 0)])
    for i in range(4):
        sched.decide("conn_reset", i)
        sched.decide("forward_error", i)
    assert sched.log.entries() == [("conn_reset", 3), ("forward_error", 0),
                                   ("forward_error", 1)]
    assert sched.log.summary() == {"conn_reset": 1, "forward_error": 2}


def test_chaos_engine_injects_forward_faults_and_passes_through():
    eng = EchoEngine()
    chaos = ChaosEngine(eng, ScriptedSchedule([("forward_error", 1)]))
    assert chaos.forward([1, 2]) == [2, 4]          # call #0: clean
    with pytest.raises(InjectedFault):
        chaos.forward([1])                          # call #1: injected
    assert chaos.forward([3]) == [6]                # call #2: clean again
    # the wrapper presents the full engine surface
    assert chaos.metrics is eng.metrics
    assert chaos.batcher.depth == 0
    assert chaos.batcher.config.max_batch == eng.batcher.config.max_batch


# ---------------------------------------------------------------------------
# supervisor: the silent-pump-death regressions
# ---------------------------------------------------------------------------
def test_supervisor_restarts_pump_killed_by_next_batch():
    """Regression: ``next_batch`` raising used to kill the pump thread for
    good — every later request then hung to its timeout. Under supervision
    the pump must come back and serve everything."""
    eng = EchoEngine()
    chaos = ChaosEngine(eng, ScriptedSchedule([("pump_crash", 0),
                                               ("pump_crash", 2)]))
    pump = EnginePump(chaos, "echo").start()
    with PumpSupervisor(pump, **FAST_SUP) as sup:
        for i in range(6):
            assert pump.call(i, timeout=10.0) == 2 * i
        deadline = time.monotonic() + 5.0
        while sup.restarts < 2 and time.monotonic() < deadline:
            time.sleep(0.005)
    assert pump.crashes == 2
    assert sup.restarts == 2 and sup.deaths == 2
    assert chaos.schedule.log.count("pump_crash") == 2
    assert pump.generation == 3          # initial spawn + two restarts
    assert eng.metrics.counters["completed"] == 6
    pump.close()
    assert sup.healthy                   # two restarts is not a crash loop


def test_supervisor_ignores_never_started_pump_and_close_is_clean():
    """Regression: the watchdog must not 'restart' a pump that was never
    started, and ``close()`` on that pump (with the supervisor still
    watching) must fail queued work out, not fight the supervisor."""
    eng = EchoEngine()
    pump = EnginePump(eng, "echo")       # never started
    req = pump.submit(1)
    with PumpSupervisor(pump, **FAST_SUP) as sup:
        time.sleep(0.05)                 # many check intervals
        assert sup.restarts == 0 and sup.deaths == 0 and sup.healthy
        pump.close(timeout=0.5)
        time.sleep(0.05)                 # draining: still not a crash
        assert sup.restarts == 0 and sup.deaths == 0
    assert req.status == "failed" and req.done.is_set()
    assert not pump.running and pump.restart() is False


def test_supervisor_crash_loop_trips_unhealthy():
    eng = EchoEngine()
    # every claim crashes: the engine can never actually serve
    chaos = ChaosEngine(eng, FaultSchedule(FaultSpec(pump_crash_rate=1.0)))
    pump = EnginePump(chaos, "echo").start()
    sup = PumpSupervisor(pump, crash_loop_threshold=3, **FAST_SUP).start()
    try:
        pump.submit(1)                   # non-empty queue => crash fodder
        deadline = time.monotonic() + 5.0
        while sup.healthy and time.monotonic() < deadline:
            time.sleep(0.005)
        assert not sup.healthy, f"never tripped: {sup.stats()}"
        assert sup.restarts > 3
    finally:
        sup.close()
        pump.close(timeout=0.5)


# ---------------------------------------------------------------------------
# circuit breaker state machine (fake clock: fully deterministic)
# ---------------------------------------------------------------------------
def test_breaker_opens_half_opens_and_closes():
    now = [0.0]
    br = CircuitBreaker(failure_threshold=2, cooldown_s=1.0,
                        clock=lambda: now[0])
    br.before(); br.record_failure()
    br.before(); br.record_failure()            # threshold reached
    assert br.state == "open" and br.opened == 1
    with pytest.raises(Unavailable) as ei:
        br.before()                             # still cooling down
    assert 0 < ei.value.retry_after_s <= 1.0
    now[0] = 1.5
    br.before()                                 # cooldown over: probe slot
    assert br.state == "half_open"
    with pytest.raises(Unavailable):
        br.before()                             # one probe at a time
    br.record_success()
    assert br.state == "closed" and br.stats()["streak"] == 0
    assert br.stats()["shed"] == 2


def test_breaker_probe_failure_reopens_and_neutral_releases_slot():
    now = [0.0]
    br = CircuitBreaker(failure_threshold=1, cooldown_s=1.0,
                        clock=lambda: now[0])
    br.before(); br.record_failure()
    assert br.state == "open"
    now[0] = 1.1
    br.before()                                 # probe
    br.record_failure()                         # probe failed: reopen
    assert br.state == "open" and br.opened == 2
    now[0] = 2.3
    br.before()                                 # new probe
    br.record_neutral()                         # backpressure: says nothing
    assert br.state == "half_open"
    br.before()                                 # slot released: probe again
    br.record_success()
    assert br.state == "closed"


def test_breaker_success_resets_streak():
    br = CircuitBreaker(failure_threshold=3)
    for _ in range(2):
        br.before(); br.record_failure()
    br.before(); br.record_success()            # intermittent, not persistent
    br.before(); br.record_failure()
    br.before(); br.record_failure()
    assert br.state == "closed"                 # streak restarted at 0


def test_breaker_bounds_500_tail_on_the_wire():
    eng = ScoreStubEngine()
    orig_forward = eng.forward
    eng.forward = lambda p: (_ for _ in ()).throw(RuntimeError("down"))
    server = GatewayServer(
        {"score": EnginePump(eng, "score")}, supervise=False,
        breaker_config={"failure_threshold": 2, "cooldown_s": 0.2}).start()
    try:
        client = GatewayClient(server.url, timeout_s=5.0, retries=0)
        tail = []
        for _ in range(5):
            with pytest.raises((Failed, Unavailable)) as ei:
                client.score([1, 2], [3, 4], timeout_s=5.0)
            tail.append(ei.type)
        # exactly `threshold` requests paid a 500; the rest shed as 503
        assert tail == [Failed] * 2 + [Unavailable] * 3
        eng.forward = orig_forward
        time.sleep(0.25)                        # cooldown; probe closes it
        assert client.score([1, 2], [3, 4], timeout_s=5.0).shape == (2,)
        assert server.breakers["score"].stats()["state"] == "closed"
        assert eng.executions == 1              # sheds never hit the engine
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# /healthz liveness (satellite: dead pump must answer 503)
# ---------------------------------------------------------------------------
def _healthz_code(url):
    try:
        with urllib.request.urlopen(url + "/healthz", timeout=5.0) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_healthz_503_when_pump_thread_dead_and_recovers_supervised():
    eng = ScoreStubEngine()
    chaos = ChaosEngine(eng, ScriptedSchedule([("pump_crash", 0)]))
    server = GatewayServer({"score": EnginePump(chaos, "score")},
                           supervise=False).start()
    try:
        code, body = _healthz_code(server.url)
        assert code == 200 and body["status"] == "ok"
        client = GatewayClient(server.url, timeout_s=2.0, retries=0)
        # first request crashes the pump thread; unsupervised => stays dead
        with pytest.raises(Timeout):
            client.score([1], [2], timeout_s=0.3)
        code, body = _healthz_code(server.url)
        assert code == 503 and body["status"] == "unhealthy"
        assert body["engines"]["score"]["running"] is False
        assert body["engines"]["score"]["crashes"] == 1
        # the tolerant client helper reports the same body instead of raising
        assert client.health()["status"] == "unhealthy"
    finally:
        server.stop()

    # same failure under supervision: request served, health stays ok
    eng2 = ScoreStubEngine()
    chaos2 = ChaosEngine(eng2, ScriptedSchedule([("pump_crash", 0)]))
    server2 = GatewayServer({"score": EnginePump(chaos2, "score")},
                            supervisor_config=FAST_SUP).start()
    try:
        client2 = GatewayClient(server2.url, timeout_s=10.0, retries=0)
        assert client2.score([1], [2], timeout_s=10.0).shape == (1,)
        code, body = _healthz_code(server2.url)
        assert code == 200 and body["status"] == "ok"
        assert body["engines"]["score"]["supervisor"]["restarts"] == 1
    finally:
        server2.stop()


# ---------------------------------------------------------------------------
# idempotency dedupe (satellite: reset retries must not double-execute)
# ---------------------------------------------------------------------------
def test_idempotency_cache_unit():
    cache = IdempotencyCache(maxsize=2)
    role, entry = cache.begin("k1")
    assert role == "primary"
    role2, entry2 = cache.begin("k1")
    assert role2 == "dup" and entry2 is entry and cache.replays == 1
    cache.resolve("k1", entry, 200, {"ok": True}, {})
    assert entry.event.is_set() and entry.response[0] == 200
    # 503 outcomes are dropped: the retry must re-execute
    _, e2 = cache.begin("k2")
    cache.resolve("k2", e2, 503, {"error": "rejected"}, {})
    role3, _ = cache.begin("k2")
    assert role3 == "primary"
    # eviction skips in-flight entries: k1 (resolved) goes, the rest —
    # all still executing — must survive even over the maxsize
    _, e3 = cache.begin("k3")                   # never resolved (in flight)
    cache.begin("k4"); cache.begin("k5")
    assert cache.stats()["entries"] == 4        # k2+k3+k4+k5, k1 evicted
    role_k1, _ = cache.begin("k1")
    assert role_k1 == "primary"                 # evicted: no replay
    role_k3, _ = cache.begin("k3")
    assert role_k3 == "dup"                     # in-flight: still deduped


def test_http_duplicate_key_replays_without_reexecuting():
    eng = ScoreStubEngine()
    server = GatewayServer({"score": EnginePump(eng, "score")},
                           supervise=False, breaker=False).start()
    try:
        data = json.dumps({"hist": [1], "candidates": [2, 3]}).encode()

        def post(key):
            req = urllib.request.Request(
                server.url + "/v1/score", data=data,
                headers={"Content-Type": "application/json",
                         "Idempotency-Key": key})
            with urllib.request.urlopen(req, timeout=5.0) as r:
                return json.loads(r.read())
        first, second = post("same-key"), post("same-key")
        assert first["scores"] == second["scores"] == [0.0, 1.0]
        assert "idempotent_replay" not in first
        assert second["idempotent_replay"] is True
        assert eng.executions == 1              # the whole point
        assert post("other-key")["scores"] == [0.0, 1.0]
        assert eng.executions == 2
    finally:
        server.stop()


def test_post_reset_retry_is_deduped_end_to_end():
    """The double-execution hazard: the server executes, the connection
    dies before the response lands, the client retries — the retry must be
    answered from the dedupe, not executed again."""
    eng = ScoreStubEngine()
    server = GatewayServer({"score": EnginePump(eng, "score")},
                           supervise=False, breaker=False).start()
    try:
        client = ChaosClient(server.url,
                             ScriptedSchedule([("conn_reset", 0)]),
                             reset_mode="post", timeout_s=5.0, retries=2,
                             backoff_s=0.01, backoff_cap_s=0.02)
        scores = client.score([1], [2, 3], timeout_s=5.0)
        assert scores.tolist() == [0.0, 1.0]
        assert client.stats["retries_conn"] == 1
        assert eng.executions == 1              # retried, never re-executed
        assert server.dedupe.stats()["replays"] == 1
    finally:
        server.stop()


def test_pre_reset_retry_reexecutes_safely():
    eng = ScoreStubEngine()
    server = GatewayServer({"score": EnginePump(eng, "score")},
                           supervise=False, breaker=False).start()
    try:
        client = ChaosClient(server.url,
                             ScriptedSchedule([("conn_reset", 0)]),
                             reset_mode="pre", timeout_s=5.0, retries=2,
                             backoff_s=0.01, backoff_cap_s=0.02)
        assert client.score([1], [2], timeout_s=5.0).shape == (1,)
        # the first attempt never reached the server: execute-once via retry
        assert eng.executions == 1
        assert server.dedupe.stats()["replays"] == 0
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# client: defensive Retry-After parse (satellite bugfix)
# ---------------------------------------------------------------------------
def test_parse_retry_after_rejects_garbage():
    assert _parse_retry_after("0.25") == 0.25
    assert _parse_retry_after("0") == 0.0
    for bad in (None, "", "never", "nan", "inf", "-1", "1e999"):
        assert _parse_retry_after(bad) is None


def test_client_survives_malformed_retry_after_header():
    srv = _scripted_server([
        (503, {"error": "rejected", "detail": "full"},
         {"Retry-After": "soonish"}),          # used to ValueError here
        (200, {"scores": [7.0]}, {}),
    ])
    try:
        client = GatewayClient(f"http://127.0.0.1:{srv.server_address[1]}",
                               retries=2, backoff_s=0.01, backoff_cap_s=0.02)
        assert client.score([1], [2]).tolist() == [7.0]
        assert client.stats["retries_503"] == 1
    finally:
        srv.shutdown()
        srv.server_close()


# ---------------------------------------------------------------------------
# GRASP cache snapshot/restore
# ---------------------------------------------------------------------------
def _small_cache(metrics=None):
    rng = np.random.default_rng(0)
    table = rng.standard_normal((64, 8), np.float32)
    cfg = CacheConfig(budget_bytes=16 * 8 * 4, hot_fraction=0.5,
                      policy="rrpv", tile_e=128)
    return EmbeddingCache(table, cfg, metrics=metrics), table


def _touch(cache, ids):
    rows, stats = cache.lookup(np.asarray(ids, np.int64))
    return np.asarray(rows), stats


def test_snapshot_restore_roundtrip_exact_state():
    cache, table = _small_cache()
    _touch(cache, [10, 20, 30, 10, 40, 20])     # populate cold region
    snap = cache.snapshot()
    assert snap["version"] == 1 and snap["checksum"]

    fresh, _ = _small_cache()
    fresh.restore(snap)
    np.testing.assert_array_equal(fresh._slot_id, cache._slot_id)
    np.testing.assert_array_equal(fresh._slot_rrpv, cache._slot_rrpv)
    assert fresh._clock == cache._clock
    # restored rows were warm-filled from the backing table
    rid = int(next(i for i in fresh._slot_id if i >= 0))
    rows, stats = _touch(fresh, [rid])
    assert stats.cold_hits == 1 and stats.misses == 0
    np.testing.assert_allclose(rows[0], table[rid], rtol=1e-6)
    # deterministic replay: the same probe hits identically on both caches
    probe = [10, 20, 55, 30, 60, 40]
    s_orig = _touch(cache, probe)[1]
    twin, _ = _small_cache()
    twin.restore(snap)
    s_twin = _touch(twin, probe)[1]
    assert (s_orig.hot_hits, s_orig.cold_hits, s_orig.misses) == \
        (s_twin.hot_hits, s_twin.cold_hits, s_twin.misses)


def test_snapshot_rejects_corruption_and_mismatch():
    cache, _ = _small_cache()
    _touch(cache, [10, 20])
    snap = cache.snapshot()

    bad = dict(snap, checksum=snap["checksum"] + 1)
    with pytest.raises(SnapshotError, match="checksum"):
        _small_cache()[0].restore(bad)

    tampered = json.loads(json.dumps(snap))
    tampered["state"]["clock"] += 7             # payload edit, stale checksum
    with pytest.raises(SnapshotError, match="checksum"):
        _small_cache()[0].restore(tampered)

    with pytest.raises(SnapshotError, match="version"):
        _small_cache()[0].restore(dict(snap, version=99))

    other = EmbeddingCache(np.zeros((32, 8), np.float32),
                           CacheConfig(budget_bytes=16 * 8 * 4,
                                       hot_fraction=0.5, tile_e=128))
    with pytest.raises(SnapshotError, match="geometry"):
        other.restore(snap)


def test_snapshot_file_roundtrip_and_missing_file(tmp_path):
    metrics = ServeMetrics()
    cache, _ = _small_cache(metrics=metrics)
    _touch(cache, [10, 20, 30])
    path = str(tmp_path / "cache.json")
    cache.save_snapshot(path)

    fresh, _ = _small_cache(metrics=ServeMetrics())
    assert fresh.load_snapshot(path) is True
    assert fresh.metrics.counters["snapshot_restores"] == 1
    assert fresh.load_snapshot(str(tmp_path / "absent.json")) is False

    with open(path) as f:
        obj = json.load(f)
    obj["state"]["slot_id"] = obj["state"]["slot_id"][::-1]
    with open(path, "w") as f:
        json.dump(obj, f)
    with pytest.raises(SnapshotError):
        _small_cache()[0].load_snapshot(path)


def test_gateway_snapshot_dir_saves_and_restores(tmp_path):
    """The server-level wiring: stop() snapshots, start() warm-restores,
    and a corrupt snapshot means a cold start, never a crash."""
    rng = np.random.default_rng(1)
    table = rng.standard_normal((64, 8), np.float32)
    cfg = CacheConfig(budget_bytes=16 * 8 * 4, hot_fraction=0.5, tile_e=128)

    class CachedStub(ScoreStubEngine):
        def __init__(self):
            super().__init__()
            self.cache = EmbeddingCache(table, cfg, metrics=self.metrics)

    eng = CachedStub()
    _touch(eng.cache, [10, 20, 30])
    server = GatewayServer({"score": EnginePump(eng, "score")},
                           snapshot_dir=str(tmp_path)).start()
    server.stop()
    path = tmp_path / "score.cache.json"
    assert path.exists()

    eng2 = CachedStub()
    server2 = GatewayServer({"score": EnginePump(eng2, "score")},
                            snapshot_dir=str(tmp_path)).start()
    server2.stop()
    assert eng2.metrics.counters["snapshot_restores"] == 1
    np.testing.assert_array_equal(eng2.cache._slot_id, eng.cache._slot_id)

    with open(path, "w") as f:
        f.write("{not json")
    eng3 = CachedStub()
    server3 = GatewayServer({"score": EnginePump(eng3, "score")},
                            snapshot_dir=str(tmp_path)).start()
    server3.stop()
    assert "snapshot_restores" not in eng3.metrics.counters


# ---------------------------------------------------------------------------
# pump restart semantics under supervision
# ---------------------------------------------------------------------------
def test_restart_supersedes_wedged_generation():
    """A wedged forward cannot be killed, only abandoned: the supervisor
    fails the batch out, a new generation serves, and the unwedged old
    thread's late completion is a no-op."""
    release = threading.Event()
    eng = EchoEngine()
    orig_forward = eng.forward

    def wedge_once(payloads, _done=[]):
        if not _done:
            _done.append(1)
            release.wait(10.0)
        return orig_forward(payloads)

    eng.forward = wedge_once
    pump = EnginePump(eng, "echo").start()
    sup = PumpSupervisor(pump, wedge_timeout_s=0.05, **FAST_SUP).start()
    try:
        with pytest.raises(Failed, match="wedged"):
            pump.call(1, timeout=10.0)          # failed out by the watchdog
        deadline = time.monotonic() + 5.0      # restart follows the fail-out
        while sup.restarts < 1 and time.monotonic() < deadline:
            time.sleep(0.005)
        assert sup.wedges == 1 and sup.restarts == 1
        assert pump.call(2, timeout=10.0) == 4  # new generation serves
        release.set()                           # old thread unwedges + exits
        time.sleep(0.05)
        assert pump.call(3, timeout=10.0) == 6  # still exactly one pump
        assert eng.metrics.counters["failed"] == 1
    finally:
        release.set()
        sup.close()
        pump.close()
