"""GRASP core: hot-vertex stats (paper Table I), reordering invariants
(paper Sec. II-E), ABR region classification (Sec. III-A/B), plan sizing."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import hotset, plan, regions, reorder
from repro.graph import datasets, generate
from repro.graph.csr import apply_reorder


@pytest.fixture(scope="module")
def g():
    return datasets.load("tw", scale=13)


def test_skew_stats_match_paper_band(g):
    """Paper Table I: hot vertices 9-26% of total, covering 81-93% of edges."""
    st_ = hotset.skew_stats(hotset.reuse_degree(g, "pull"))
    assert 0.05 < st_.hot_fraction < 0.30
    assert st_.edge_coverage > 0.75


def test_uniform_graph_has_no_skew():
    g = generate.uniform(12, 16, seed=1)
    st_ = hotset.skew_stats(hotset.reuse_degree(g, "pull"))
    # no-skew: hot set covers roughly its population share of edges
    assert st_.edge_coverage < 0.75


@pytest.mark.parametrize("technique", reorder.TECHNIQUES)
def test_reorder_is_permutation(g, technique):
    rank = reorder.reorder_ranks(g, technique)
    assert np.array_equal(np.sort(rank), np.arange(g.num_nodes))


@pytest.mark.parametrize("technique", ["sort", "hubsort", "dbg", "gorder_lite"])
def test_reorder_segregates_hot_prefix(g, technique):
    """After skew-aware reordering the hottest vertices form a prefix
    (paper Fig. 3a) — prefix mean degree >> tail mean degree."""
    rank = reorder.reorder_ranks(g, technique)
    g2 = apply_reorder(g, rank)
    deg = hotset.reuse_degree(g2, "pull")
    k = g.num_nodes // 8
    assert deg[:k].mean() > 10 * deg[-k:].mean()


def test_reorder_preserves_edges(g):
    rank = reorder.reorder_ranks(g, "dbg")
    g2 = apply_reorder(g, rank)
    assert g2.num_edges == g.num_edges
    # spot-check: edge (u -> v) maps to (rank[u] -> rank[v])
    src, dst = g.indices[:100], g.dst_ids()[:100]
    s2 = set(zip(g2.indices.tolist(), g2.dst_ids().tolist()))
    for u, v in zip(rank[src].tolist(), rank[dst].tolist()):
        assert (u, v) in s2


def test_sort_is_degree_descending(g):
    rank = reorder.reorder_ranks(g, "sort")
    g2 = apply_reorder(g, rank)
    deg = hotset.reuse_degree(g2, "pull")
    assert np.all(np.diff(deg) <= 0)


def test_regions_classification():
    r = regions.make_regions([(0, 1000)], llc_bytes=100)
    addr = np.array([0, 50, 99, 100, 150, 199, 200, 500, 999, 1000, 5000])
    hint = r.classify(addr)
    assert hint.tolist() == [0, 0, 0, 1, 1, 1, 2, 2, 2, 3, 3]


def test_regions_multiple_arrays_divide_budget():
    r = regions.make_regions([(0, 1000), (2000, 3000)], llc_bytes=100)
    assert r.region_bytes == 50  # paper: LLC size / num arrays
    assert r.classify(np.array([49]))[0] == regions.HIGH
    assert r.classify(np.array([50]))[0] == regions.MODERATE
    assert r.classify(np.array([2049]))[0] == regions.HIGH


@given(
    n=st.integers(100, 10_000),
    elem=st.sampled_from([4, 8, 16]),
    budget=st.integers(64, 1 << 16),
)
@settings(max_examples=25, deadline=None)
def test_plan_properties(n, elem, budget):
    p = plan.make_plan(n, elem, budget_bytes=budget)
    assert 0 <= p.hot_size <= n
    assert p.hot_size * elem <= budget
    assert p.hot_size + p.moderate_size <= n
    cls = p.classify_elem(np.arange(n))
    # classification is monotone: hot prefix, then moderate, then cold
    assert np.all(np.diff(cls) >= 0)
    if p.hot_size:
        assert cls[0] == 0 and cls[p.hot_size - 1] == 0
        if p.hot_size < n:
            assert cls[p.hot_size] != 0


def test_plan_regions_consistent_with_elem_classification():
    p = plan.make_plan(4096, 8, budget_bytes=4096)
    r = p.regions()
    idx = np.arange(4096)
    byte_cls = r.classify(idx * 8)
    elem_cls = p.classify_elem(idx)
    assert np.array_equal(byte_cls[elem_cls == 0], np.zeros((p.hot_size,)))
    assert np.all(byte_cls[elem_cls == 2] == 2)
