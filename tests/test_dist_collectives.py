"""GRASP distributed exchange: partition invariants + bit-exact equivalence
with the unpartitioned reference (subprocess: needs >1 device)."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_partition_covers_all_edges_with_generous_caps():
    from repro.core.reorder import reorder_ranks
    from repro.dist import collectives as coll
    from repro.graph import generate
    from repro.graph.csr import apply_reorder

    g = generate.rmat(8, 6, seed=1)
    g = apply_reorder(g, reorder_ranks(g, "dbg"))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 4, hot=64,
                                   pub_frac=1.0, edge_slack=3.0)
    part = coll.grasp_partition(g, spec)
    assert part["dropped"] == 0
    assert part["emask"].sum() == g.num_edges
    # every esrc index is inside the 3-region table
    assert (part["esrc"][part["emask"]] >= 0).all()
    assert (part["esrc"][part["emask"]] < spec.table_len).all()
    assert (part["edst"][part["emask"]] < spec.n_own).all()


def test_partition_halo_is_bounded_by_skew():
    """Paper Table I at the partition tier: with the hot prefix replicated,
    the halo (cold remote sources) covers only the cold edge fraction."""
    from repro.core.reorder import reorder_ranks
    from repro.dist import collectives as coll
    from repro.graph import generate
    from repro.graph.csr import apply_reorder

    g = generate.rmat(10, 10, seed=2)
    g = apply_reorder(g, reorder_ranks(g, "dbg"))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 8,
                                   hot=g.num_nodes // 8, pub_frac=1.0,
                                   edge_slack=3.0)
    part = coll.grasp_partition(g, spec)
    published = int((part["pub"] > 0).sum())
    # the skew guarantee: most edge SOURCES are hot (replicated -> free),
    # so halo traffic is the minority path...
    hot_src_frac = float((g.indices < spec.hot).mean())
    assert hot_src_frac > 0.6
    # ...and the publish buffers respect their static capacity
    assert published <= spec.num_devices * spec.c_pub


@pytest.mark.slow
def test_grasp_exchange_matches_reference_subprocess():
    """shard_map GRASP exchange == unpartitioned GIN loss, on 8 devices."""
    r = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "helpers", "grasp_gnn_equivalence.py")],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
