"""GRASP distributed exchange: partition invariants + bit-exact equivalence
with the unpartitioned reference (subprocess: needs >1 device)."""
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_partition_covers_all_edges_with_generous_caps():
    from repro.core.reorder import reorder_ranks
    from repro.dist import collectives as coll
    from repro.graph import generate
    from repro.graph.csr import apply_reorder

    g = generate.rmat(8, 6, seed=1)
    g = apply_reorder(g, reorder_ranks(g, "dbg"))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 4, hot=64,
                                   pub_frac=1.0, edge_slack=3.0)
    part = coll.grasp_partition(g, spec)
    assert part["dropped"] == 0
    assert part["emask"].sum() == g.num_edges
    # every esrc index is inside the 3-region table
    assert (part["esrc"][part["emask"]] >= 0).all()
    assert (part["esrc"][part["emask"]] < spec.table_len).all()
    assert (part["edst"][part["emask"]] < spec.n_own).all()


def test_partition_halo_is_bounded_by_skew():
    """Paper Table I at the partition tier: with the hot prefix replicated,
    the halo (cold remote sources) covers only the cold edge fraction."""
    from repro.core.reorder import reorder_ranks
    from repro.dist import collectives as coll
    from repro.graph import generate
    from repro.graph.csr import apply_reorder

    g = generate.rmat(10, 10, seed=2)
    g = apply_reorder(g, reorder_ranks(g, "dbg"))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 8,
                                   hot=g.num_nodes // 8, pub_frac=1.0,
                                   edge_slack=3.0)
    part = coll.grasp_partition(g, spec)
    published = int((part["pub"] > 0).sum())
    # the skew guarantee: most edge SOURCES are hot (replicated -> free),
    # so halo traffic is the minority path...
    hot_src_frac = float((g.indices < spec.hot).mean())
    assert hot_src_frac > 0.6
    # ...and the publish buffers respect their static capacity
    assert published <= spec.num_devices * spec.c_pub


def test_pipelined_step_matches_sequential_single_device():
    """The overlap=True (default) pipelined exchange must be bit-exact vs
    overlap=False. On one device every all_gather is an identity, but the
    whole pipelined code path (prologue exchange, fused hot+halo buffer,
    double-buffered feature tables) still executes — the 8-device run is
    the slow subprocess test below."""
    import jax
    import jax.numpy as jnp

    from repro.configs import base as cfgs
    from repro.core.reorder import reorder_ranks
    from repro.dist import collectives as coll
    from repro.graph import generate
    from repro.graph.csr import apply_reorder
    from repro.launch.mesh import make_debug_mesh
    from repro.nn import gnn as gnn_mod
    from repro.train import optimizer as opt_mod

    mesh = make_debug_mesh(1, 1)
    g = generate.rmat(7, 5, seed=4)
    g = apply_reorder(g, reorder_ranks(g, "dbg"))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 1, hot=32,
                                   pub_frac=1.0, edge_slack=3.0)
    part = coll.grasp_partition(g, spec)
    assert part["dropped"] == 0

    cfg = cfgs.GNNConfig(name="t1", kind="gin", n_layers=3, d_hidden=8)
    d_feat, n_classes = 6, 4
    rng = np.random.default_rng(0)
    params0 = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=d_feat)
    opt_init, opt_update = opt_mod.make(opt_mod.OptConfig(lr=1e-3))
    x = rng.standard_normal((spec.num_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, spec.num_nodes).astype(np.int32)
    batch = dict(
        x_hot=jnp.asarray(x[:spec.hot]),
        x_cold=jnp.asarray(x[spec.hot:].reshape(1, spec.cold_per_dev, d_feat)),
        esrc=jnp.asarray(part["esrc"]), edst=jnp.asarray(part["edst"]),
        emask=jnp.asarray(part["emask"]), pub=jnp.asarray(part["pub"]),
        labels=jnp.asarray(labels[None, :]))

    results = {}
    for overlap in (False, True):
        step, _ = coll.make_grasp_gin_step(spec, cfg, d_feat, n_classes,
                                           mesh, opt_update, overlap=overlap)
        p_, o_ = params0, opt_init(params0)
        losses = []
        with jax.set_mesh(mesh):
            jstep = jax.jit(step)
            for _ in range(3):
                p_, o_, m = jstep(p_, o_, batch)
                losses.append(float(m["loss"]))
        results[overlap] = (losses, p_)

    assert results[False][0] == results[True][0]
    for a, b in zip(jax.tree_util.tree_leaves(results[False][1]),
                    jax.tree_util.tree_leaves(results[True][1])):
        assert bool((a == b).all())


@pytest.mark.slow
def test_grasp_exchange_matches_reference_subprocess():
    """shard_map GRASP exchange == unpartitioned GIN loss, on 8 devices."""
    r = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "helpers", "grasp_gnn_equivalence.py")],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]


@pytest.mark.slow
def test_pipelined_step_bit_exact_subprocess():
    """Pipelined (overlap=True) == sequential GRASP step: identical loss
    and params over 3 layers x 5 steps on the 8-device mesh."""
    r = subprocess.run(
        [sys.executable, os.path.join(os.path.dirname(__file__), "helpers", "grasp_pipeline_equivalence.py")],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stdout[-1500:] + r.stderr[-1500:]
