"""grasp_partition edge cases: hot=0 (no-skew robustness), a single device,
and node counts not divisible by the device count (padding round-trip)."""
import numpy as np

from repro.core.reorder import reorder_ranks
from repro.dist import collectives as coll
from repro.graph import generate
from repro.graph.csr import apply_reorder, from_edges


def _dbg(g):
    return apply_reorder(g, reorder_ranks(g, "dbg"))


def _check_invariants(g, spec, part):
    kept = part["esrc"][part["emask"]]
    assert (kept >= 0).all() and (kept < spec.table_len).all()
    assert (part["edst"][part["emask"]] < spec.n_own).all()
    assert part["dropped"] == g.num_edges - int(part["emask"].sum())


def test_partition_hot_zero_no_skew_graph():
    """GRASP degrades gracefully when nothing is classified hot: every
    cross-device source must flow through the halo, and with pub_frac=1
    nothing drops."""
    g = generate.uniform(8, 4, seed=3)
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 4, hot=0,
                                   pub_frac=1.0, edge_slack=4.0)
    assert spec.hot == 0 and spec.hot_per_dev == 0
    part = coll.grasp_partition(g, spec)
    assert part["dropped"] == 0
    assert int(part["emask"].sum()) == g.num_edges
    _check_invariants(g, spec, part)


def test_partition_single_device_has_no_halo():
    """P=1: everything is owned locally, so the publish buffers stay empty
    and no edge can drop regardless of pub_frac."""
    g = _dbg(generate.rmat(7, 5, seed=4))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 1, hot=32,
                                   pub_frac=0.01, edge_slack=1.0)
    part = coll.grasp_partition(g, spec)
    assert part["dropped"] == 0
    assert (part["pub"] == 0).all()
    assert int(part["emask"].sum()) == g.num_edges
    _check_invariants(g, spec, part)


def test_partition_pads_non_divisible_node_count():
    """num_nodes % P != 0: the spec pads the cold region up to a full
    per-device slice and the partition must still cover every edge."""
    rng = np.random.default_rng(0)
    n = 1013  # prime: not divisible by 8
    src = rng.integers(0, n, 6000)
    dst = rng.integers(0, n, 6000)
    g = from_edges(src, dst, n)
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 8, hot=64,
                                   pub_frac=1.0, edge_slack=4.0)
    assert spec.num_nodes >= n
    assert spec.hot + spec.num_devices * spec.cold_per_dev == spec.num_nodes
    assert spec.num_nodes % spec.num_devices == 0 or spec.hot % spec.num_devices == 0
    part = coll.grasp_partition(g, spec)
    assert part["dropped"] == 0
    assert int(part["emask"].sum()) == g.num_edges
    _check_invariants(g, spec, part)


def test_partition_tight_caps_account_exactly():
    """Undersized halo/edge budgets MAY drop edges, but the bookkeeping and
    the static capacity bounds must hold exactly."""
    g = _dbg(generate.rmat(8, 8, seed=5))
    spec = coll.partition_spec_for(g.num_nodes, g.num_edges, 4, hot=32,
                                   pub_frac=0.05, edge_slack=0.5)
    part = coll.grasp_partition(g, spec)
    _check_invariants(g, spec, part)
    assert int((part["pub"] > 0).sum()) <= spec.num_devices * spec.c_pub
