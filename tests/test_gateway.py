"""repro.gateway: pump lifecycle, HTTP round-trips, client retry/backoff.

The pump tests run against a jax-free echo engine so the concurrency
machinery is exercised in isolation; the server tests then put the real
recsys/LM engines behind loopback sockets and check the served answers
against the dense references — the cache+pump+HTTP path must move rows,
never values.
"""
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np
import pytest

from repro.gateway import (
    EnginePump,
    Failed,
    GatewayClient,
    GatewayServer,
    Rejected,
    Shed,
    Timeout,
)
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import ContinuousBatcher, SchedulerConfig


class EchoEngine:
    """Minimal ``_EngineBase`` surface: doubles integer payloads."""

    def __init__(self, sched=None, delay_s=0.0):
        self.metrics = ServeMetrics()
        self.batcher = ContinuousBatcher(
            sched or SchedulerConfig(max_batch=4, max_queue=8),
            metrics=self.metrics)
        self.delay_s = delay_s
        self.boom = False

    def forward(self, payloads):
        if self.boom:
            raise RuntimeError("boom")
        if self.delay_s:
            time.sleep(self.delay_s)
        return [p * 2 for p in payloads]


# ---------------------------------------------------------------------------
# pump
# ---------------------------------------------------------------------------
def test_pump_concurrent_callers_get_own_results():
    eng = EchoEngine()
    with EnginePump(eng, "echo") as pump:
        results = {}

        def call(i):
            results[i] = pump.call(i, timeout=10.0)

        threads = [threading.Thread(target=call, args=(i,)) for i in range(16)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(10.0)
        assert results == {i: 2 * i for i in range(16)}
    assert not pump.running
    assert eng.metrics.counters["completed"] == 16


def test_pump_failed_forward_resolves_with_typed_error_and_survives():
    eng = EchoEngine()
    with EnginePump(eng, "echo") as pump:
        eng.boom = True
        with pytest.raises(Failed):
            pump.call(1, timeout=10.0)
        # the pump thread survived the exception and keeps serving
        eng.boom = False
        assert pump.call(2, timeout=10.0) == 4
    assert eng.metrics.counters["failed"] == 1


def test_pump_shed_request_raises_shed():
    eng = EchoEngine()
    pump = EnginePump(eng, "echo")
    req = pump.submit(1, deadline_s=1e-4)   # pump not started yet
    time.sleep(0.01)                        # deadline passes in queue
    pump.start()
    with pytest.raises(Shed):
        pump.result(req, timeout=10.0)
    assert req.done.is_set() and req.status == "shed"
    pump.close()


def test_pump_result_timeout():
    eng = EchoEngine()
    pump = EnginePump(eng, "echo")          # never started: nothing drains
    req = pump.submit(1)
    with pytest.raises(Timeout):
        pump.result(req, timeout=0.05)
    pump.close(timeout=1.0)
    # close() failed the stranded request out instead of leaving it queued
    assert req.status == "failed" and req.done.is_set()


def test_pump_drain_closes_admissions_and_finishes_inflight():
    eng = EchoEngine(delay_s=0.01)
    pump = EnginePump(eng, "echo").start()
    reqs = [pump.submit(i) for i in range(8)]
    assert pump.drain(timeout=30.0)
    assert all(r.status == "done" for r in reqs)
    with pytest.raises(Rejected):
        pump.submit(99)
    pump.close()


def test_pump_rejects_when_queue_full():
    eng = EchoEngine(sched=SchedulerConfig(max_batch=2, max_queue=3))
    pump = EnginePump(eng, "echo")          # not started: queue only fills
    for i in range(3):
        pump.submit(i)
    with pytest.raises(Rejected):
        pump.submit(3)
    assert eng.metrics.counters["rejected"] == 1
    pump.close(timeout=1.0)


# ---------------------------------------------------------------------------
# HTTP server round-trips (real engines, loopback sockets)
# ---------------------------------------------------------------------------
def test_server_score_roundtrip_matches_dense_reference():
    import jax
    import jax.numpy as jnp

    from repro.configs import base as cfgs
    from repro.nn import recsys as recsys_mod
    from repro.serve.cache import CacheConfig
    from repro.serve.engine import RecsysServeEngine

    cfg = cfgs.reduced(cfgs.get_arch("mind"))
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    eng = RecsysServeEngine(
        params, cfg,
        CacheConfig(budget_bytes=64 * cfg.embed_dim * 4, tile_e=128),
        SchedulerConfig(max_batch=4, max_queue=16))
    eng.warmup(candidates=8)
    rng = np.random.default_rng(0)
    hist = rng.integers(0, cfg.n_items, cfg.hist_len)
    cand = rng.integers(0, cfg.n_items, 8)

    with GatewayServer({"score": EnginePump(eng, "score")}) as server:
        client = GatewayClient(server.url, timeout_s=30.0)
        assert client.health()["status"] == "ok"
        scores = client.score(hist.tolist(), cand.tolist(), timeout_s=30.0)
        snap = client.metrics()["score"]
        # malformed requests answer 400 without entering the pump
        from repro.gateway import GatewayError
        with pytest.raises(GatewayError, match="ids must be in"):
            client._request("/v1/score", {"hist": [int(cfg.n_items)],
                                          "candidates": [0]})
        with pytest.raises(GatewayError):
            client._request("/v1/nope", {})

    ref = np.asarray(recsys_mod.serve_scores(params, cfg, {
        "hist": jnp.asarray(hist[None]),
        "hist_mask": jnp.ones((1, cfg.hist_len), bool),
        "candidates": jnp.asarray(cand[None]),
    }))[0]
    np.testing.assert_allclose(scores, ref, rtol=1e-5, atol=1e-5)
    assert snap["counters"]["completed"] == 1
    assert 0.0 < snap["hit_rate"] <= 1.0


def test_server_generate_roundtrip_deterministic():
    from repro.serve.engine import LMServeEngine

    eng = LMServeEngine(arch="minitron-8b", smoke=True,
                        sched_config=SchedulerConfig(max_batch=2, max_queue=8),
                        prefill=8, decode=4)
    eng.warmup()
    prompt = [1, 2, 3, 4, 5]
    with GatewayServer({"generate": EnginePump(eng, "generate")}) as server:
        client = GatewayClient(server.url, timeout_s=60.0)
        out1 = client.generate(prompt, timeout_s=60.0)
        out2 = client.generate(prompt, timeout_s=60.0)
    assert len(out1) == 4 and out1 == out2          # greedy => deterministic
    assert eng.metrics.counters["tokens_generated"] == 8
    ref = eng.forward([{"tokens": np.asarray(prompt)}])[0]
    assert out1 == ref.tolist()


def test_server_drain_rejects_new_work():
    eng = EchoEngine()
    server = GatewayServer({"score": EnginePump(eng, "echo")}).start()
    url = server.url
    client = GatewayClient(url, timeout_s=5.0, retries=0)
    server.stop()
    # after stop the listener is gone: the client surfaces a typed/transport
    # error instead of hanging
    with pytest.raises(Exception):
        client._request("/v1/score", {"hist": [0], "candidates": [0]})


# ---------------------------------------------------------------------------
# client retry behaviour against a scripted stub server
# ---------------------------------------------------------------------------
class _ScriptedHandler(BaseHTTPRequestHandler):
    def log_message(self, *a):
        pass

    def do_POST(self):
        self.rfile.read(int(self.headers.get("Content-Length") or 0))
        code, body, headers = (self.server.script.pop(0) if self.server.script
                               else (200, {"scores": [1.0]}, {}))
        payload = json.dumps(body).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(payload)))
        for k, v in headers.items():
            self.send_header(k, v)
        self.end_headers()
        self.wfile.write(payload)


def _scripted_server(script):
    srv = ThreadingHTTPServer(("127.0.0.1", 0), _ScriptedHandler)
    srv.daemon_threads = True
    srv.script = list(script)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv


def test_client_retries_transient_503_then_recovers():
    srv = _scripted_server([
        (503, {"error": "rejected", "detail": "full"}, {"Retry-After": "0.01"}),
        (503, {"error": "shed", "detail": "late"}, {"Retry-After": "0.01"}),
        (200, {"scores": [3.5]}, {}),
    ])
    try:
        client = GatewayClient(f"http://127.0.0.1:{srv.server_address[1]}",
                               retries=4, backoff_s=0.01, backoff_cap_s=0.05)
        scores = client.score([1], [2])
        assert scores.tolist() == [3.5]
        assert client.stats["retries_503"] == 2
        assert client.stats["recovered"] == 1
    finally:
        srv.shutdown()
        srv.server_close()


def test_client_raises_typed_errors_without_retrying_non_503():
    srv = _scripted_server([
        (504, {"error": "timeout", "detail": "budget"}, {}),
        (500, {"error": "failed", "detail": "boom"}, {}),
        (503, {"error": "rejected", "detail": "full"}, {}),
        (503, {"error": "rejected", "detail": "full"}, {}),
    ])
    try:
        url = f"http://127.0.0.1:{srv.server_address[1]}"
        client = GatewayClient(url, retries=1, backoff_s=0.01,
                               backoff_cap_s=0.02)
        with pytest.raises(Timeout):
            client.score([1], [2])
        with pytest.raises(Failed):
            client.score([1], [2])
        # retries exhausted on persistent 503 -> typed Rejected, not a hang
        with pytest.raises(Rejected):
            client.score([1], [2])
        assert client.stats["retries_503"] == 1
    finally:
        srv.shutdown()
        srv.server_close()
