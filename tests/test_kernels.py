"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode —
CPU container; TPU is the lowering target)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.embedding_bag import ref as eb_ref
from repro.kernels.hot_gather import ops as hg_ops
from repro.kernels.hot_gather import ref as hg_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "n,d,e,hot",
    [
        (1000, 8, 4096, 256),
        (5000, 64, 8192, 1024),
        (300, 130, 2048, 300),    # d not lane-aligned; hot == n (all hot)
        (4096, 16, 2048, 64),     # tiny hot region
    ],
)
def test_hot_gather_sweep(n, d, e, hot, dtype):
    key = jax.random.PRNGKey(0)
    prop = jax.random.normal(key, (n, d), dtype=jnp.float32).astype(dtype)
    idx = jax.random.randint(key, (e,), 0, n, dtype=jnp.int32)
    idx = jnp.where(jax.random.uniform(key, (e,)) < 0.85, idx % max(hot, 1), idx)
    out = hg_ops.hot_gather(prop, idx, hot_size=hot)
    ref = hg_ref.gather_ref(prop, idx)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=1e-6
    )


def test_hot_gather_no_skew_degrades_gracefully():
    """All-cold indices (paper Fig. 9 adversarial case): result still exact."""
    key = jax.random.PRNGKey(1)
    prop = jax.random.normal(key, (2048, 32))
    idx = jax.random.randint(key, (4096,), 1024, 2048, dtype=jnp.int32)
    out = hg_ops.hot_gather(prop, idx, hot_size=1024)
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.take(prop, idx, axis=0)), atol=1e-6)


def test_hot_gather_cold_capacity_bound():
    """Bounded cold fixup: capacity >= actual cold count stays exact."""
    key = jax.random.PRNGKey(2)
    prop = jax.random.normal(key, (1024, 16))
    idx = jnp.concatenate([
        jnp.zeros((3800,), jnp.int32),                      # hot
        jnp.arange(512, 808, dtype=jnp.int32),              # 296 cold
    ])
    out = hg_ops.hot_gather(prop, idx, hot_size=512, cold_capacity=512)
    np.testing.assert_allclose(np.asarray(out), np.asarray(jnp.take(prop, idx, axis=0)), atol=1e-6)


def test_fused_gather_segsum_aligned():
    from repro.graph import generate
    from repro.kernels.hot_gather.ops import (
        build_aligned_edges, hot_gather_segsum_aligned)

    g = generate.uniform(9, 6, seed=0)
    idx_t, seg_t, n_pad = build_aligned_edges(
        g.indptr, g.indices, seg_per_tile=64, tile_e=512
    )
    if idx_t.shape[0] // 512 * 64 != n_pad:
        pytest.skip("oversized tiles for fused path on this graph")
    key = jax.random.PRNGKey(0)
    prop = jax.random.normal(key, (g.num_nodes, 32))
    out = hot_gather_segsum_aligned(
        prop, jnp.asarray(idx_t), jnp.asarray(seg_t), n_pad, 64, tile_e=512
    )
    rows = jnp.where(
        jnp.asarray(idx_t)[:, None] >= 0,
        jnp.take(prop, jnp.asarray(np.maximum(idx_t, 0)), axis=0), 0.0,
    )
    ref = jax.ops.segment_sum(rows, jnp.asarray(seg_t), num_segments=n_pad)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "v,d,b,h,hot",
    [(2000, 16, 512, 8, 256), (5000, 64, 300, 12, 512), (1000, 100, 64, 4, 1000)],
)
def test_hot_bag_sweep(v, d, b, h, hot):
    key = jax.random.PRNGKey(0)
    table = jax.random.normal(key, (v, d))
    ids = jax.random.randint(key, (b, h), 0, v, dtype=jnp.int32)
    ids = jnp.where(jax.random.uniform(key, (b, h)) < 0.8, ids % hot, ids)
    mask = jax.random.uniform(key, (b, h)) < 0.9
    out = eb_ops.hot_bag(table, ids, mask, hot_size=hot)
    ref = eb_ref.bag_ref(table, ids, mask)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_hot_bag_all_masked():
    key = jax.random.PRNGKey(3)
    table = jax.random.normal(key, (256, 8))
    ids = jax.random.randint(key, (32, 4), 0, 256, dtype=jnp.int32)
    out = eb_ops.hot_bag(table, ids, jnp.zeros((32, 4), bool), hot_size=64)
    assert float(jnp.abs(out).max()) == 0.0


def test_hot_lookup_matches_take():
    key = jax.random.PRNGKey(4)
    table = jax.random.normal(key, (4096, 64))
    ids = jax.random.randint(key, (2048,), 0, 4096, dtype=jnp.int32)
    out = hg_ops.hot_gather(table, ids, hot_size=512)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(eb_ref.lookup_ref(table, ids)), atol=1e-6
    )
