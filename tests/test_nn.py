"""NN substrate: transformer decode==forward consistency, GNN equivariance,
MoE routing semantics, MIND shapes/gradients."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgs
from repro.nn import gnn as gnn_mod
from repro.nn import layers as L
from repro.nn import recsys as recsys_mod
from repro.nn import transformer as tfm


@pytest.fixture(scope="module")
def tiny_cfg():
    return cfgs.LMConfig(
        name="tiny", n_layers=2, d_model=32, n_heads=4, n_kv=2, d_ff=64,
        vocab=97, act="silu", gated=True, remat=False, microbatches=1,
    )


def test_decode_matches_forward(tiny_cfg):
    """Teacher-forcing equivalence: full forward logits at position t ==
    decode-with-cache logits after consuming t tokens. This pins down RoPE
    offsets, causal masking and the cache update in one test."""
    cfg = tiny_cfg
    key = jax.random.PRNGKey(0)
    params = tfm.init(key, cfg)
    tokens = jax.random.randint(key, (2, 12), 0, cfg.vocab, dtype=jnp.int32)

    full_logits, _ = tfm.forward(params, cfg, tokens)
    # prefill on the first 8, decode the next 4
    logits_p, cache = tfm.prefill(params, cfg, tokens[:, :8], max_len=16)
    np.testing.assert_allclose(
        np.asarray(logits_p), np.asarray(full_logits[:, 7]), rtol=0.06, atol=5e-2
    )
    for t in range(8, 12):
        logits_d, cache = tfm.decode_step(params, cfg, cache, tokens[:, t])
        np.testing.assert_allclose(
            np.asarray(logits_d), np.asarray(full_logits[:, t]),
            rtol=0.06, atol=5e-2,
        )


def test_chunked_loss_matches_full(tiny_cfg):
    cfg = tiny_cfg
    key = jax.random.PRNGKey(1)
    params = tfm.init(key, cfg)
    b = {
        "tokens": jax.random.randint(key, (2, 1024), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(key, (2, 1024), 0, cfg.vocab, jnp.int32),
    }
    loss = tfm.loss_fn(params, cfg, b)  # 1024 -> 2 chunks
    logits, aux = tfm.forward(params, cfg, b["tokens"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, b["labels"][..., None], axis=-1)[..., 0]
    ref = -ll.mean() + 0.01 * aux
    assert float(jnp.abs(loss - ref)) < 1e-3


def test_train_step_reduces_loss(tiny_cfg):
    from repro.train import optimizer as opt_mod

    cfg = tiny_cfg
    key = jax.random.PRNGKey(2)
    params = tfm.init(key, cfg)
    opt_init, opt_update = opt_mod.make(opt_mod.OptConfig(name="adamw", lr=3e-3))
    opt_state = opt_init(params)
    b = {
        "tokens": jax.random.randint(key, (4, 64), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(key, (4, 64), 0, cfg.vocab, jnp.int32),
    }

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(tfm.loss_fn)(p, cfg, b)
        p, o = opt_update(g, o, p)
        return p, o, loss

    losses = []
    for _ in range(8):
        params, opt_state, loss = step(params, opt_state)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_moe_routing_topk_mass():
    key = jax.random.PRNGKey(0)
    p = L.moe_init(key, 16, 32, n_experts=4, gated=True)
    x = jax.random.normal(key, (64, 16))
    out, aux = L.moe(p, x, top_k=2, capacity_factor=2.0)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()
    assert float(aux) > 0.0  # load-balance loss is positive


def test_moe_capacity_drop_is_bounded():
    """With capacity_factor=2 and uniform tokens, dropped mass ~ 0: MoE out
    should differ from zero for nearly all tokens."""
    key = jax.random.PRNGKey(1)
    p = L.moe_init(key, 8, 16, n_experts=4, gated=False)
    x = jax.random.normal(key, (256, 8))
    out, _ = L.moe(p, x, top_k=1, capacity_factor=2.0)
    nonzero = np.asarray(jnp.abs(out).sum(axis=-1) > 0)
    assert nonzero.mean() > 0.95


def _rot():
    # a fixed 3D rotation matrix
    a, b, c = 0.3, 1.1, -0.7
    rx = np.array([[1, 0, 0], [0, np.cos(a), -np.sin(a)], [0, np.sin(a), np.cos(a)]])
    ry = np.array([[np.cos(b), 0, np.sin(b)], [0, 1, 0], [-np.sin(b), 0, np.cos(b)]])
    rz = np.array([[np.cos(c), -np.sin(c), 0], [np.sin(c), np.cos(c), 0], [0, 0, 1]])
    return (rx @ ry @ rz).astype(np.float32)


def _mol_batch(rng, n=20, e=60, d=8):
    return {
        "x": rng.standard_normal((n, d)).astype(np.float32),
        "src": rng.integers(0, n, e).astype(np.int32),
        "dst": rng.integers(0, n, e).astype(np.int32),
        "emask": np.ones(e, bool),
        "coords": rng.standard_normal((n, 3)).astype(np.float32),
        "species": rng.integers(0, 8, n).astype(np.int32),
    }


def test_egnn_equivariance():
    cfg = cfgs.GNNConfig(name="t", kind="egnn", n_layers=2, d_hidden=16)
    rng = np.random.default_rng(0)
    batch = _mol_batch(rng)
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=8)
    h1, c1 = gnn_mod.apply(params, cfg, batch)
    R = _rot()
    b2 = dict(batch, coords=batch["coords"] @ R.T)
    h2, c2 = gnn_mod.apply(params, cfg, b2)
    # invariant features, equivariant coordinates
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(c1) @ R.T, np.asarray(c2), atol=2e-4)


def test_egnn_translation_equivariance():
    cfg = cfgs.GNNConfig(name="t", kind="egnn", n_layers=2, d_hidden=16)
    rng = np.random.default_rng(1)
    batch = _mol_batch(rng)
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=8)
    h1, c1 = gnn_mod.apply(params, cfg, batch)
    shift = np.array([5.0, -3.0, 2.0], np.float32)
    b2 = dict(batch, coords=batch["coords"] + shift)
    h2, c2 = gnn_mod.apply(params, cfg, b2)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(c1) + shift, np.asarray(c2), atol=2e-4)


def test_nequip_rotation_invariance():
    cfg = cfgs.GNNConfig(name="t", kind="nequip", n_layers=2, d_hidden=8,
                         l_max=2, n_rbf=4, cutoff=5.0)
    rng = np.random.default_rng(2)
    batch = _mol_batch(rng)
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=8)
    e1 = gnn_mod.apply(params, cfg, batch)
    R = _rot()
    b2 = dict(batch, coords=batch["coords"] @ R.T)
    e2 = gnn_mod.apply(params, cfg, b2)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), atol=1e-4)


def test_gin_isomorphism_sum_agg():
    """GIN with sum aggregation distinguishes multisets: doubling an edge
    changes the target's embedding (mean-agg would not for equal msgs)."""
    cfg = cfgs.GNNConfig(name="t", kind="gin", n_layers=1, d_hidden=8)
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=4)
    x = np.ones((3, 4), np.float32)
    b1 = {"x": x, "src": np.array([1], np.int32), "dst": np.array([0], np.int32),
          "emask": np.ones(1, bool)}
    b2 = {"x": x, "src": np.array([1, 2], np.int32),
          "dst": np.array([0, 0], np.int32), "emask": np.ones(2, bool)}
    o1 = np.asarray(gnn_mod.apply(params, cfg, b1))
    o2 = np.asarray(gnn_mod.apply(params, cfg, b2))
    assert np.abs(o1[0] - o2[0]).max() > 1e-5


def test_pna_aggregators_shapes():
    cfg = cfgs.GNNConfig(name="t", kind="pna", n_layers=2, d_hidden=16)
    rng = np.random.default_rng(3)
    batch = _mol_batch(rng, n=30, e=100, d=8)
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=8)
    out = gnn_mod.apply(params, cfg, batch)
    assert out.shape == (30, cfg.d_out)
    assert np.isfinite(np.asarray(out)).all()


def test_mind_interests_and_loss():
    cfg = cfgs.reduced(cfgs.RecsysConfig(name="mind"))
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    hist = rng.integers(0, cfg.n_items, (16, cfg.hist_len)).astype(np.int32)
    mask = np.ones_like(hist, bool)
    interests = recsys_mod.user_interests(params, cfg, jnp.asarray(hist),
                                          jnp.asarray(mask))
    assert interests.shape == (16, cfg.n_interests, cfg.embed_dim)
    batch = {
        "hist": jnp.asarray(hist), "hist_mask": jnp.asarray(mask),
        "target": jnp.asarray(rng.integers(0, cfg.n_items, 16, ).astype(np.int32)),
        "negatives": jnp.asarray(rng.integers(0, cfg.n_items, 32).astype(np.int32)),
    }
    loss, grads = jax.value_and_grad(recsys_mod.loss_fn)(params, cfg, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree_util.tree_leaves(grads))
    assert gn > 0.0


def test_mind_serve_and_retrieval_consistency():
    cfg = cfgs.reduced(cfgs.RecsysConfig(name="mind"))
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    hist = jnp.asarray(rng.integers(0, cfg.n_items, (1, cfg.hist_len)).astype(np.int32))
    mask = jnp.ones_like(hist, dtype=bool)
    cands = jnp.asarray(rng.integers(0, cfg.n_items, 128).astype(np.int32))
    serve = recsys_mod.serve_scores(
        params, cfg, {"hist": hist, "hist_mask": mask,
                      "candidates": cands[None, :]})
    retr = recsys_mod.retrieval_scores(
        params, cfg, {"hist": hist, "hist_mask": mask, "candidates": cands})
    np.testing.assert_allclose(np.asarray(serve), np.asarray(retr), rtol=1e-5)
