"""repro.serve: GRASP embedding cache, continuous-batching scheduler,
metrics, and the serving engines.

The cache tests all pivot on one invariant: whatever the region geometry
or eviction pressure, ``lookup(ids)`` returns exactly ``table[ids]`` — the
cache moves rows, never values.
"""
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.serve.cache import CacheConfig, EmbeddingCache, LookupStats
from repro.serve.metrics import ServeMetrics
from repro.serve.refcache import ReferenceEmbeddingCache
from repro.serve.scheduler import (
    ContinuousBatcher,
    SchedulerConfig,
    VirtualClock,
)

N, D = 512, 8
ROW = D * 4


def _table(n=N, d=D, seed=0):
    return np.random.default_rng(seed).standard_normal((n, d)).astype(np.float32)


def _cache(table, rows, hot_fraction=0.5, **kw):
    cc = CacheConfig(budget_bytes=rows * table.shape[1] * 4,
                     hot_fraction=hot_fraction, tile_e=128, **kw)
    return EmbeddingCache(table, cc)


def _ref_check(cache, table, ids):
    out, stats = cache.lookup(ids)
    np.testing.assert_array_equal(np.asarray(out), table[np.asarray(ids)])
    cache.check_consistency()
    return stats


# ---------------------------------------------------------------------------
# sizing
# ---------------------------------------------------------------------------
def test_entries_for_budget():
    assert plan_mod.entries_for_budget(1024, 32) == 32
    assert plan_mod.entries_for_budget(1024, 32, align=5) == 30
    assert plan_mod.entries_for_budget(1 << 30, 32, max_entries=100) == 100
    assert plan_mod.entries_for_budget(0, 32) == 0
    assert plan_mod.entries_for_budget(31, 32) == 0


def test_partition_spec_budget_sizing():
    """dist hot-replica sizing now derives from a byte budget (ROADMAP)."""
    from repro.dist import collectives as coll

    spec = coll.partition_spec_for(10_000, 50_000, 4,
                                   hot_budget_bytes=1000 * 16, elem_bytes=16)
    assert spec.hot == 1000  # 1000 rows afforded; already a multiple of 4
    # explicit hot still wins (test/ablation path)
    assert coll.partition_spec_for(10_000, 50_000, 4, hot=64).hot == 64
    # default budget (64 MiB) clamps to the graph
    assert coll.partition_spec_for(100, 400, 4).hot == 100


def test_cache_regions_sized_from_bytes():
    table = _table()
    c = _cache(table, rows=64, hot_fraction=0.5)
    assert c.capacity == 64 and c.hot_size == 32 and c.cold_slots == 32
    assert c.pin_ratio == pytest.approx(0.5)
    # degree stats cap the pinned region at the true hot-vertex count
    degree = np.zeros(N)
    degree[:10] = 100.0  # only 10 vertices are >= average degree
    cc = CacheConfig(budget_bytes=64 * ROW, hot_fraction=0.5, tile_e=128)
    c2 = EmbeddingCache(table, cc, degree=degree)
    assert c2.hot_size == 10 and c2.capacity == 64 and c2.cold_slots == 54


# ---------------------------------------------------------------------------
# eviction edge cases (ISSUE satellite)
# ---------------------------------------------------------------------------
def test_cold_start_fill_matches_dense_gather():
    table = _table()
    c = _cache(table, rows=N)          # hot 256 + cold 256: working set fits
    rng = np.random.default_rng(1)
    ids = rng.integers(0, N, 100)
    st = _ref_check(c, table, ids)     # empty cache: every unique cold fills
    uniq_cold = np.unique(ids[ids >= c.hot_size]).size
    assert st.misses == uniq_cold and st.bypassed == 0
    st2 = _ref_check(c, table, ids)    # same batch again: all hits
    assert st2.misses == 0 and st2.hit_rate == 1.0


def test_hot_region_larger_than_table():
    table = _table()
    c = _cache(table, rows=4 * N, hot_fraction=1.0)  # budget >> table
    assert c.hot_size == N and c.cold_slots == 0
    st = _ref_check(c, table, np.arange(N))
    assert st.hot_hits == N and st.misses == 0


def test_zero_capacity_cold_region():
    table = _table()
    c = _cache(table, rows=32, hot_fraction=1.0)     # all budget pinned
    assert c.hot_size == 32 and c.cold_slots == 0
    ids = np.array([0, 1, 31, 32, 100, 100, N - 1])
    st = _ref_check(c, table, ids)
    assert st.hot_hits == 3
    # cold refs can never be cached: every one is a bypassed miss
    assert st.misses == 4 and st.bypassed == 4
    st2 = _ref_check(c, table, ids)
    assert st2.misses == 4  # still — nothing was retained


def test_duplicate_ids_within_one_batch():
    table = _table()
    c = _cache(table, rows=32, hot_fraction=0.5)
    rid = c.hot_size + 7
    ids = np.array([rid] * 5 + [3] * 2)              # 5 cold dups + 2 hot dups
    st = _ref_check(c, table, ids)
    assert st.hot_hits == 2
    assert st.misses == 1                            # one fill serves all dups
    assert st.cold_hits == 4


def test_eviction_under_pressure_keeps_correctness():
    """Working set far beyond capacity, many batches; residency stays
    bounded and every answer matches the dense gather."""
    table = _table()
    c = _cache(table, rows=24, hot_fraction=0.25)    # hot 6 + cold 18
    rng = np.random.default_rng(2)
    for _ in range(10):
        ids = np.minimum(rng.zipf(1.2, 200) - 1, N - 1)
        _ref_check(c, table, ids)
        assert int((c._slot_id >= 0).sum()) <= c.cold_slots


def test_lru_policy_and_no_kernel_path():
    table = _table()
    rng = np.random.default_rng(3)
    for kw in ({"policy": "lru"}, {"use_kernel": False}):
        c = _cache(table, rows=48, **kw)
        for _ in range(4):
            _ref_check(c, table, rng.integers(0, N, 64))


def test_unpinned_baseline_has_no_hot_region():
    c = _cache(_table(), rows=64, hot_fraction=0.0)
    assert c.hot_size == 0 and c.cold_slots == 64 and c.pin_ratio == 0.0


def test_out_of_range_ids_rejected():
    c = _cache(_table(), rows=16)
    with pytest.raises(IndexError):
        c.lookup(np.array([N]))
    with pytest.raises(IndexError):
        c.lookup(np.array([-1]))


def test_empty_lookup_short_circuits():
    """Empty id batches return a (0, d) block and zero-count stats without
    ticking the eviction clock or disturbing residency (ISSUE satellite)."""
    table = _table()
    c = _cache(table, rows=32)
    _ref_check(c, table, np.arange(c.hot_size, c.hot_size + 8))
    clock, resident = c._clock, c._resident
    out, st = c.lookup(np.array([], dtype=np.int64))
    assert np.asarray(out).shape == (0, D)
    assert st == LookupStats()          # all-zero counts
    assert st.hit_rate == 0.0
    assert c._clock == clock and c._resident == resident
    c.check_consistency()
    # still works mid-stream: the next real batch is unaffected
    st2 = _ref_check(c, table, np.arange(c.hot_size, c.hot_size + 8))
    assert st2.misses == 0


def test_vectorized_lookup_matches_reference_loop():
    """The batched eviction/insert path must be bit-identical to the
    retained pre-vectorization loop: same rows, same stats, same
    cold-region metadata, under both policies and heavy thrashing."""
    table = _table()
    for policy in ("rrpv", "lru"):
        for rows, hot_fraction in ((24, 0.25), (32, 0.5), (8, 0.0)):
            cc = CacheConfig(budget_bytes=rows * ROW, hot_fraction=hot_fraction,
                             policy=policy, tile_e=128, use_kernel=False)
            vec = EmbeddingCache(table, cc)
            ref = ReferenceEmbeddingCache(table, cc)
            rng = np.random.default_rng(hash((policy, rows)) % 2**31)
            for bi in range(12):
                if bi == 5:
                    ids = np.array([], dtype=np.int64)   # empty mid-stream
                elif bi % 2:
                    ids = np.minimum(rng.zipf(1.2, 96) - 1, N - 1)
                else:
                    ids = rng.integers(0, N, 96)
                o_v, s_v = vec.lookup(ids)
                o_r, s_r = ref.lookup(ids)
                np.testing.assert_array_equal(np.asarray(o_v), np.asarray(o_r))
                np.testing.assert_array_equal(np.asarray(o_v),
                                              table[np.asarray(ids, np.int64)])
                assert s_v == s_r
            for attr in ("_slot_id", "_slot_rrpv", "_slot_ts", "_id_slot"):
                np.testing.assert_array_equal(getattr(vec, attr),
                                              getattr(ref, attr))
            assert vec.metrics.counters == ref.metrics.counters
            assert vec.metrics.hit_rate == ref.metrics.hit_rate
            vec.check_consistency()
            ref.check_consistency()


def test_resident_counter_tracks_occupancy_incrementally():
    """cold_resident is now an O(1) counter, not a full-capacity scan: it
    must equal the true occupancy after fills, evictions, and restore."""
    table = _table()
    c = _cache(table, rows=24, hot_fraction=0.25)      # hot 6 + cold 18
    assert c._resident == 0
    rng = np.random.default_rng(4)
    for _ in range(6):
        c.lookup(rng.integers(0, N, 64))
        assert c._resident == int((c._slot_id >= 0).sum())
    assert c.metrics.gauges["cold_resident"] == c._resident
    snap = c.snapshot()
    c2 = _cache(table, rows=24, hot_fraction=0.25)
    c2.restore(snap)
    assert c2._resident == int((c2._slot_id >= 0).sum()) == c._resident
    c2.check_consistency()


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------
def test_admission_control_rejects_when_full():
    clock = VirtualClock()
    b = ContinuousBatcher(SchedulerConfig(max_batch=2, max_queue=3), clock)
    reqs = [b.submit({"i": i}) for i in range(5)]
    assert [r.status for r in reqs] == ["queued"] * 3 + ["rejected"] * 2
    assert b.metrics.counters["admitted"] == 3
    assert b.metrics.counters["rejected"] == 2


def test_shed_expired_and_edf_order():
    clock = VirtualClock()
    b = ContinuousBatcher(SchedulerConfig(max_batch=2, max_queue=10), clock)
    late = b.submit("late", deadline_s=0.5)
    soon = b.submit("soon", deadline_s=0.2)
    dead = b.submit("dead", deadline_s=0.05)
    nodl = b.submit("best-effort")
    clock.advance(0.1)                       # "dead" expires
    batch = b.next_batch()
    assert dead.status == "shed"
    # earliest deadline first; best-effort sorts last
    assert [r.payload for r in batch] == ["soon", "late"]
    assert late.status == soon.status == "running"
    batch2 = b.next_batch()
    assert [r.payload for r in batch2] == ["best-effort"]
    assert nodl.status == "running"
    assert b.metrics.counters["shed"] == 1


def test_latency_accounting_virtual_time():
    clock = VirtualClock()
    b = ContinuousBatcher(SchedulerConfig(max_batch=4, max_queue=8), clock)
    b.submit("x")
    clock.advance(0.25)                      # waits 250ms in queue
    batch = b.next_batch()
    clock.advance(0.1)                       # 100ms of service
    b.complete(batch, ["ok"])
    assert batch[0].result == "ok" and batch[0].status == "done"
    snap = b.metrics.snapshot()
    assert snap["latency"]["queue_wait"]["max_s"] == pytest.approx(0.25)
    assert snap["latency"]["service"]["max_s"] == pytest.approx(0.1)
    assert snap["latency"]["e2e"]["max_s"] == pytest.approx(0.35)


# ---------------------------------------------------------------------------
# scheduler concurrency (gateway-facing guarantees)
# ---------------------------------------------------------------------------
def test_concurrent_submit_admits_exactly_max_queue():
    import threading

    Q, threads_n, per_thread = 16, 8, 10
    b = ContinuousBatcher(SchedulerConfig(max_batch=4, max_queue=Q),
                          VirtualClock())
    reqs = []
    lock = threading.Lock()

    def submitter(k):
        mine = [b.submit({"t": k, "i": i}) for i in range(per_thread)]
        with lock:
            reqs.extend(mine)

    ts = [threading.Thread(target=submitter, args=(k,))
          for k in range(threads_n)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(30.0)
    assert len(reqs) == threads_n * per_thread
    admitted = [r for r in reqs if r.status == "queued"]
    rejected = [r for r in reqs if r.status == "rejected"]
    assert len(admitted) == Q == b.depth
    assert len(rejected) == threads_n * per_thread - Q
    assert b.metrics.counters["admitted"] == Q
    # rejects resolve synchronously: nobody ever blocks on them
    assert all(r.done.is_set() for r in rejected)
    assert not any(r.done.is_set() for r in admitted)


def test_edf_equal_deadlines_stable_arrival_order():
    clock = VirtualClock()
    b = ContinuousBatcher(SchedulerConfig(max_batch=8, max_queue=16), clock)
    # same virtual arrival instant AND same deadline: ties must break by
    # submission order (rid), not dict/sort accidents
    reqs = [b.submit(i, deadline_s=1.0) for i in range(6)]
    batch = b.next_batch()
    assert [r.payload for r in batch] == list(range(6))
    assert [r.rid for r in batch] == [r.rid for r in reqs]


def test_shed_and_completed_requests_resolve_events():
    clock = VirtualClock()
    b = ContinuousBatcher(SchedulerConfig(max_batch=2, max_queue=8), clock)
    doomed = b.submit("doomed", deadline_s=0.01)
    kept = b.submit("kept", deadline_s=10.0)
    assert not doomed.done.is_set() and not kept.done.is_set()
    clock.advance(0.1)
    batch = b.next_batch()
    assert doomed.status == "shed" and doomed.done.is_set()
    assert doomed.wait(0.0) and doomed.finished is not None
    assert not kept.done.is_set()            # running, not terminal
    b.complete(batch, ["ok"])
    assert kept.done.is_set() and kept.result == "ok"


def test_failed_batch_resolves_events_with_error():
    b = ContinuousBatcher(SchedulerConfig(max_batch=4, max_queue=8),
                          VirtualClock())
    reqs = [b.submit(i) for i in range(3)]
    batch = b.next_batch()
    boom = RuntimeError("forward exploded")
    b.fail(batch, boom)
    assert all(r.status == "failed" and r.done.is_set() and r.error is boom
               for r in reqs)
    assert b.metrics.counters["failed"] == 3


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
def test_histogram_percentiles_and_json(tmp_path):
    m = ServeMetrics()
    for v in [0.001] * 98 + [0.5] * 2:
        m.observe("e2e", v)
    p50, p99 = m.hists["e2e"].percentile(50), m.hists["e2e"].percentile(99)
    assert 0.001 <= p50 <= 0.002          # upper-edge estimate, one bucket up
    assert 0.5 <= p99 <= 1.0
    assert m.hists["e2e"].max == pytest.approx(0.5)  # max is exact
    m.count("misses", 3)
    m.count("hot_hits", 7)
    assert m.hit_rate == pytest.approx(0.7)
    out = tmp_path / "snap.json"
    snap = m.write_json(str(out), extra={"tag": "t"})
    import json

    assert json.loads(out.read_text()) == snap
    assert snap["tag"] == "t"


# ---------------------------------------------------------------------------
# engines
# ---------------------------------------------------------------------------
def test_histogram_overflow_bucket_reports_exact_max():
    """Regression: samples past the last finite edge (~134s) used to read
    back the last edge for any percentile landing in the overflow bucket —
    now they fall back to the exact tracked max."""
    from repro.serve.metrics import LatencyHistogram, _EDGES

    h = LatencyHistogram()
    for v in [0.001] * 98 + [200.0, 500.0]:
        h.observe(v)
    # any percentile landing in the overflow bucket reports the exact max
    # (not the ~134s last edge, and not a quantized estimate)
    assert h.percentile(99) == pytest.approx(500.0)
    assert h.percentile(100) == pytest.approx(500.0)
    assert h.percentile(50) <= 0.002          # mid-range unaffected
    # only overflow samples: every percentile reports the exact max
    h2 = LatencyHistogram()
    h2.observe(float(_EDGES[-1]) * 4)
    h2.observe(float(_EDGES[-1]) * 8)
    for p in (50, 99, 100):
        assert h2.percentile(p) == pytest.approx(float(_EDGES[-1]) * 8)


def test_metrics_thread_safe_under_concurrent_mutation():
    import threading

    m = ServeMetrics()
    N, per = 8, 500

    def hammer(k):
        for i in range(per):
            m.count("hot_hits")
            m.observe("e2e", 0.001 * (k + 1))
            m.gauge("last", float(i))

    ts = [threading.Thread(target=hammer, args=(k,)) for k in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join(30.0)
    snap = m.snapshot()
    assert snap["counters"]["hot_hits"] == N * per
    assert snap["latency"]["e2e"]["count"] == N * per
    assert snap["latency"]["e2e"]["max_s"] == pytest.approx(0.008)


def test_recsys_engine_matches_dense_serve_scores():
    """Cache-fed serving == the reference dense-table forward."""
    import jax
    import jax.numpy as jnp

    from repro.configs import base as cfgs
    from repro.nn import recsys as recsys_mod
    from repro.serve.engine import RecsysServeEngine

    cfg = cfgs.reduced(cfgs.get_arch("mind"))
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    nreq = 5
    payloads = [{
        "hist": rng.integers(0, cfg.n_items, cfg.hist_len).astype(np.int32),
        "hist_mask": rng.random(cfg.hist_len) < 0.9,
        "candidates": rng.integers(0, cfg.n_items, 16).astype(np.int32),
    } for _ in range(nreq)]

    eng = RecsysServeEngine(
        params, cfg,
        CacheConfig(budget_bytes=64 * cfg.embed_dim * 4, tile_e=128),
        SchedulerConfig(max_batch=4, max_queue=16),
        clock=VirtualClock(), service_model=lambda n: 1e-3,
    )
    reqs = [eng.submit(p) for p in payloads]
    eng.run_until_idle()
    assert all(r.status == "done" for r in reqs)

    batch = {k: jnp.asarray(np.stack([p[k] for p in payloads]))
             for k in payloads[0]}
    ref = np.asarray(recsys_mod.serve_scores(params, cfg, batch))
    got = np.stack([r.result for r in reqs])
    np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)
    assert eng.metrics.counters["completed"] == nreq
    assert eng.metrics.counters["batches"] == 2  # 4 + 1 (partial, padded)


def test_gnn_engine_blocks_match_dense_gather():
    """GIN forward over cache-gathered features == dense-gathered features."""
    import jax
    import jax.numpy as jnp

    from repro.configs import base as cfgs
    from repro.graph import generate, sampler
    from repro.nn import gnn as gnn_mod
    from repro.serve.engine import GNNServeEngine

    g = generate.rmat(8, 4, seed=0)                  # 256 nodes, power-law
    cfg = cfgs.reduced(cfgs.get_arch("gin-tu"))
    rng = np.random.default_rng(0)
    feats = rng.standard_normal((g.num_nodes, 8)).astype(np.float32)
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg, 8)
    eng = GNNServeEngine(
        params, cfg, g, feats,
        CacheConfig(budget_bytes=64 * 8 * 4, tile_e=128),
        SchedulerConfig(max_batch=2, max_queue=8),
        fanout=(3, 3), seeds_per_req=2, clock=VirtualClock(),
        service_model=lambda n: 1e-3,
    )
    blocks = sampler.sample_blocks(g, np.array([1, 5, 9, 200]), (3, 3),
                                   np.random.default_rng(7))
    got = eng.forward_blocks(blocks)
    x = jnp.where(jnp.asarray(blocks.node_mask)[:, None],
                  jnp.asarray(feats[blocks.node_ids]), 0.0)
    ref = gnn_mod.apply(params, cfg, {
        "x": x, "src": jnp.asarray(blocks.src), "dst": jnp.asarray(blocks.dst),
        "emask": jnp.asarray(blocks.emask),
    })
    np.testing.assert_allclose(got, np.asarray(ref)[blocks.seeds_local],
                               rtol=1e-5, atol=1e-6)
    # queued path: per-request logits with the right shape
    r1 = eng.submit({"seeds": np.array([0, 1])})
    r2 = eng.submit({"seeds": np.array([2, 3])})
    eng.run_until_idle()
    assert eng.metrics.counters["completed"] == 2
    assert r1.result.shape == r2.result.shape == (2, cfg.d_out)
    assert np.isfinite(r1.result).all()


def test_lm_loop_partial_batch_counts_served_tokens():
    """requests % batch != 0: the loop must serve exactly requests*decode
    tokens (the old driver padded the last batch and misreported)."""
    from repro.serve.engine import lm_loop

    stats = lm_loop(arch="minitron-8b", smoke=True, requests=5, batch=4,
                    prefill=8, decode=4)
    assert stats["requests"] == 5
    assert stats["tokens"] == 5 * 4


def test_launch_serve_cli_recsys(tmp_path):
    from repro.launch import serve as serve_cli

    out = tmp_path / "s.json"
    snap = serve_cli.main([
        "--engine", "recsys", "--requests", "24", "--batch", "4",
        "--qps", "1e9", "--budget-kb", "4", "--deadline-ms", "1e9",
        "--json", str(out),
    ])
    assert snap["counters"]["completed"] == 24
    assert 0.0 < snap["hit_rate"] <= 1.0
    assert out.exists()
