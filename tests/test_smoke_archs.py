"""Per-architecture smoke tests: REDUCED config, one forward/train step on
CPU, assert output shapes + no NaNs (assignment requirement f).

The FULL configs are exercised only via the dry-run (ShapeDtypeStruct —
launch/dryrun.py, separate process with 512 placeholder devices)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import base as cfgs
from repro.data import pipeline
from repro.nn import gnn as gnn_mod
from repro.nn import recsys as recsys_mod
from repro.nn import transformer as tfm
from repro.train import optimizer as opt_mod

LM_ARCHS = ["moonshot-v1-16b-a3b", "phi3.5-moe-42b-a6.6b", "minitron-8b",
            "starcoder2-7b", "nemotron-4-340b"]
GNN_ARCHS = ["egnn", "nequip", "gin-tu", "pna"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = cfgs.reduced(cfgs.get_arch(arch))
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    shape = cfgs.LMShape("smoke", "train", 64, 4)
    batch = jax.tree_util.tree_map(
        jnp.asarray, pipeline.lm_batch(rng, cfg, 4, 64)
    )
    opt_init, opt_update = opt_mod.make(opt_mod.OptConfig(lr=1e-3))
    opt_state = opt_init(params)
    loss, grads = jax.value_and_grad(tfm.loss_fn)(params, cfg, batch)
    new_params, _ = opt_update(grads, opt_state, params)
    assert np.isfinite(float(loss)) and float(loss) > 0
    for leaf in jax.tree_util.tree_leaves(new_params):
        assert np.isfinite(np.asarray(leaf, dtype=np.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_decode(arch):
    cfg = cfgs.reduced(cfgs.get_arch(arch))
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    tokens = jnp.zeros((2, 8), jnp.int32)
    logits, cache = tfm.prefill(params, cfg, tokens, max_len=16)
    assert logits.shape == (2, cfg.vocab)
    logits2, cache = tfm.decode_step(params, cfg, cache,
                                     jnp.zeros((2,), jnp.int32))
    assert logits2.shape == (2, cfg.vocab)
    assert int(cache.length) == 9
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", GNN_ARCHS)
@pytest.mark.parametrize("kind", ["full_graph", "molecule", "minibatch"])
def test_gnn_smoke_all_shapes(arch, kind):
    cfg = cfgs.reduced(cfgs.get_arch(arch))
    rng = np.random.default_rng(0)
    if kind == "full_graph":
        shape = cfgs.GNNShape("s", "full_graph", 256, 1024, d_feat=16)
        batch = pipeline.gnn_full_graph_batch(rng, shape, scale_override=8)
    elif kind == "molecule":
        shape = cfgs.GNNShape("s", "molecule", 10, 20, d_feat=16, batch_graphs=4)
        batch = pipeline.gnn_molecule_batch(rng, shape)
    else:
        from repro.graph import generate

        g = generate.rmat(8, 8, seed=0)
        shape = cfgs.GNNShape("s", "minibatch", g.num_nodes, g.num_edges,
                              d_feat=16, batch_nodes=8, fanout=(3, 2))
        batch = pipeline.gnn_minibatch(rng, g, shape, d_feat=16)
    batch = jax.tree_util.tree_map(jnp.asarray, batch)
    params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=16)
    out = gnn_mod.apply(params, cfg, batch)
    out = out[0] if isinstance(out, tuple) else out
    assert np.isfinite(np.asarray(out)).all()
    n_nodes = batch["x"].shape[0]
    if cfg.kind == "nequip":
        assert out.shape == (n_nodes,)
    elif cfg.kind == "egnn":
        assert out.shape == (n_nodes, cfg.d_out)
    else:
        assert out.shape == (n_nodes, cfg.d_out)


def test_gnn_smoke_train_step_loss():
    """One optimizer step through the cell loss for each GNN kind."""
    from repro.launch.steps import _gnn_loss

    rng = np.random.default_rng(1)
    for arch in GNN_ARCHS:
        cfg = cfgs.reduced(cfgs.get_arch(arch))
        shape = cfgs.GNNShape("s", "molecule", 10, 20, d_feat=16, batch_graphs=4)
        batch = pipeline.gnn_molecule_batch(rng, shape)
        if cfg.kind in ("gin", "pna"):
            batch["labels"] = rng.integers(0, cfg.d_out, 4).astype(np.int32)
        batch = jax.tree_util.tree_map(jnp.asarray, batch)
        params = gnn_mod.init(jax.random.PRNGKey(0), cfg, d_feat=16)
        loss, grads = jax.value_and_grad(_gnn_loss)(params, cfg, batch)
        assert np.isfinite(float(loss)), arch
        gn = sum(float(jnp.abs(g).sum())
                 for g in jax.tree_util.tree_leaves(grads))
        assert np.isfinite(gn) and gn > 0, arch


def test_mind_smoke_all_shapes():
    cfg = cfgs.reduced(cfgs.get_arch("mind"))
    rng = np.random.default_rng(0)
    params = recsys_mod.init(jax.random.PRNGKey(0), cfg)
    train = pipeline.recsys_batch(rng, cfg, cfgs.RecsysShape("t", "train", 16))
    loss = recsys_mod.loss_fn(params, cfg,
                              jax.tree_util.tree_map(jnp.asarray, train))
    assert np.isfinite(float(loss))
    serve = pipeline.recsys_batch(rng, cfg, cfgs.RecsysShape("s", "serve", 8))
    scores = recsys_mod.serve_scores(
        params, cfg, jax.tree_util.tree_map(jnp.asarray, serve))
    assert scores.shape == (8, 64) and np.isfinite(np.asarray(scores)).all()
    retr = pipeline.recsys_batch(
        rng, cfg, cfgs.RecsysShape("r", "retrieval", 1, n_candidates=1000))
    rs = recsys_mod.retrieval_scores(
        params, cfg, jax.tree_util.tree_map(jnp.asarray, retr))
    assert rs.shape == (1, 1000) and np.isfinite(np.asarray(rs)).all()


def test_sampler_shapes_and_validity():
    from repro.graph import generate, sampler

    g = generate.rmat(10, 8, seed=0)
    rng = np.random.default_rng(0)
    seeds = rng.integers(0, g.num_nodes, 16)
    blocks = sampler.sample_blocks(g, seeds, (5, 3), rng)
    n_sub, e_sub = sampler.subgraph_shape(16, (5, 3))
    assert blocks.node_ids.shape == (n_sub,)
    assert blocks.src.shape == (e_sub,)
    # every valid edge's sampled neighbour is a true in-neighbour
    indptr, indices = g.indptr, g.indices
    for k in rng.integers(0, e_sub, 50):
        if not blocks.emask[k]:
            continue
        dst_g = blocks.node_ids[blocks.dst[k]]
        src_g = blocks.node_ids[blocks.src[k]]
        nbrs = indices[indptr[dst_g]:indptr[dst_g + 1]]
        assert src_g in nbrs
