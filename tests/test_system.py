"""End-to-end behaviour tests: the paper's full pipeline (reorder -> trace
-> LLC policies -> claims), the training driver with failure injection, and
a production-mesh dry-run in a subprocess."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_grasp_pipeline_end_to_end():
    """Paper headline claims, end to end on a scaled dataset:
    GRASP reduces misses vs RRIP (Fig. 5), lands between RRIP and OPT
    (Fig. 11), and never slows down (speed-up proxy >= 1)."""
    from repro.core import cachesim
    from repro.core.reorder import reorder_ranks
    from repro.graph import datasets, traces
    from repro.graph.csr import apply_reorder

    g = datasets.load("pl", scale=13)
    g2 = apply_reorder(g, reorder_ranks(g, "dbg"))
    llc = datasets.scaled_llc_bytes("pl", g2, elem_bytes=16)
    tr, plan = traces.generate_trace(g2, "pr", llc, max_records=500_000)
    res = {p: cachesim.simulate(tr, p, llc)
           for p in ("rrip", "grasp", "opt", "lru")}
    assert res["grasp"].misses < res["rrip"].misses
    assert res["opt"].misses < res["grasp"].misses
    assert res["rrip"].misses < res["lru"].misses
    pm = cachesim.PerfModel()
    assert pm.speedup(res["rrip"], res["grasp"]) > 1.0
    # Fig. 2: the Property Array dominates LLC accesses
    prop_accesses = res["rrip"].accesses_by_hint[:2].sum()  # High+Moderate
    assert prop_accesses > 0


def test_train_driver_with_failures(tmp_path):
    """examples-style run: tiny LM, checkpoints, two injected failures; the
    loop must recover and produce a decreasing loss."""
    from repro.launch import train as train_mod

    state = train_mod.main([
        "--arch", "minitron-8b", "--smoke", "--steps", "30",
        "--batch", "4", "--seq", "32", "--ckpt", str(tmp_path),
        "--fail-at", "7", "19",
    ])
    assert state is not None


@pytest.mark.slow
def test_dryrun_subprocess_small_mesh(tmp_path):
    """The real dry-run entry point on a 512-device host (one cell) —
    proves the XLA_FLAGS bootstrap + lower + compile path headlessly."""
    out = tmp_path / "dry.json"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--mesh", "single",
         "--cells", "gin-tu:molecule", "--out", str(out)],
        env={**os.environ, "PYTHONPATH": SRC},
        capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    rec = json.loads(out.read_text())
    assert rec[0]["status"] == "ok"
    assert rec[0]["devices"] == 256
