"""LLC trace generation: structure, hints, L1 filtering (paper Sec. II-C)."""
import numpy as np
import pytest

from repro.core.regions import DEFAULT, HIGH, LOW
from repro.graph import datasets, traces
from repro.graph.csr import apply_reorder
from repro.core.reorder import reorder_ranks


@pytest.fixture(scope="module")
def setup():
    g = datasets.load("lj", scale=12)
    g2 = apply_reorder(g, reorder_ranks(g, "dbg"))
    llc = datasets.scaled_llc_bytes("lj", g2, elem_bytes=16)
    tr, plan = traces.generate_trace(g2, "pr", llc)
    return g2, llc, tr, plan


def test_trace_has_all_pc_streams(setup):
    _, _, tr, _ = setup
    assert set(np.unique(tr.pc)) == {0, 1, 2, 3}


def test_property_gathers_dominate(setup):
    """Paper Fig. 2: gathers (pc 0) dominate the access stream."""
    _, _, tr, _ = setup
    assert (tr.pc == 0).mean() > 0.6


def test_hints_match_plan_regions(setup):
    g2, _, tr, plan = setup
    # High-hinted accesses are property lines inside the hot byte range
    hi = tr.line[tr.hint == HIGH] * 64
    assert hi.max() < plan.hot_size * plan.elem_bytes
    # streaming arrays (pc 1,2) are always Low-Reuse (paper Sec. III-B)
    assert np.all(tr.hint[(tr.pc == 1) | (tr.pc == 2)] == LOW)


def test_l1_filter_removes_consecutive_dups(setup):
    _, _, tr, _ = setup
    for p in range(4):
        lines = tr.line[tr.pc == p]
        if lines.size > 1:
            assert np.all(lines[1:] != lines[:-1]), f"pc{p} has L1-filterable dups"


def test_hints_disabled_yields_default(setup):
    g2, llc, _, _ = setup
    tr, _ = traces.generate_trace(g2, "pr", llc, hints_enabled=False)
    assert np.all(tr.hint == DEFAULT)


def test_next_use_consistency(setup):
    _, _, tr, _ = setup
    rng = np.random.default_rng(0)
    for t in rng.integers(0, tr.length, 200):
        nxt = tr.nxt[t]
        if nxt < tr.length:
            assert tr.line[nxt] == tr.line[t]
            # no intermediate occurrence
            assert not np.any(tr.line[t + 1 : nxt] == tr.line[t])


def test_push_direction_uses_out_edges():
    g = datasets.load("lj", scale=11)
    llc = 32 * 1024
    tr_pull, _ = traces.generate_trace(g, "pr", llc)
    tr_push, _ = traces.generate_trace(g, "sssp", llc)
    assert tr_pull.length > 0 and tr_push.length > 0
    assert tr_pull.length != tr_push.length  # different traversals
