"""Training substrate: optimizers, checkpoint roundtrip + elasticity, FT
restart bit-exactness, straggler watchdog, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.train import checkpoint as ckpt_mod
from repro.train import compression as comp
from repro.train import ft as ft_mod
from repro.train import optimizer as opt_mod


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizer_minimizes_quadratic(name):
    init, update = opt_mod.make(opt_mod.OptConfig(name=name, lr=0.1,
                                                  weight_decay=0.0))
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.ones((4, 8)) * 2.0}
    state = init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        params, state = update(g, state, params)
    assert float(loss(params)) < 0.05 * l0


def test_adamw_bf16_moments_memory():
    init, _ = opt_mod.make(opt_mod.OptConfig(name="adamw", moment_dtype="bfloat16"))
    state = init({"w": jnp.zeros((128, 128))})
    assert state["m"]["w"].dtype == jnp.bfloat16


def test_adafactor_state_is_factored():
    init, _ = opt_mod.make(opt_mod.OptConfig(name="adafactor"))
    state = init({"w": jnp.zeros((256, 512))})
    v = state["v"]["w"]
    assert v["vr"].shape == (256,) and v["vc"].shape == (512,)
    # factored state is ~(r+c)/(r*c) of Adam's second moment


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}
    ckpt_mod.save(str(tmp_path), 7, tree)
    assert ckpt_mod.latest_step(str(tmp_path)) == 7
    like = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
    )
    out = ckpt_mod.restore(str(tmp_path), None, like)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_checkpoint_retention(tmp_path):
    tree = {"x": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        ckpt_mod.save(str(tmp_path), s, tree)
    ckpt_mod.retain(str(tmp_path), keep=2)
    steps = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert steps == ["step_4", "step_5"]
    assert ckpt_mod.latest_step(str(tmp_path)) == 5


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto explicit (1-device) shardings — the elastic path."""
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ckpt_mod.save(str(tmp_path), 1, tree)
    out = ckpt_mod.restore(str(tmp_path), 1, tree, shardings={"w": sh})
    assert out["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(8))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def _counter_run(tmp_path, fail_at=()):
    def init_state():
        return {"x": jnp.zeros((3,)), "steps_seen": jnp.zeros((), jnp.int32)}

    def step_fn(state, step):
        return {
            "x": state["x"] + step,              # depends on exact step ids
            "steps_seen": state["steps_seen"] + 1,
        }

    return ft_mod.run_with_restarts(
        init_state, step_fn, num_steps=25, ckpt_dir=str(tmp_path),
        ckpt_every=5, injector=ft_mod.FailureInjector(fail_at=fail_at),
    )


def test_ft_restart_bit_exact(tmp_path):
    clean = _counter_run(tmp_path / "clean")
    faulty = _counter_run(tmp_path / "faulty", fail_at=(7, 12, 23))
    assert faulty.restarts == 3
    np.testing.assert_array_equal(np.asarray(clean.state["x"]),
                                  np.asarray(faulty.state["x"]))


def test_ft_too_many_failures_raises(tmp_path):
    with pytest.raises(ft_mod.InjectedFailure):
        ft_mod.run_with_restarts(
            lambda: {"x": jnp.zeros(())},
            lambda s, i: s,
            num_steps=10,
            ckpt_dir=str(tmp_path),
            injector=ft_mod.FailureInjector(fail_at=tuple(range(10))),
            max_restarts=3,
        )


def test_straggler_watchdog_detects_and_decides():
    wd = ft_mod.StragglerWatchdog(window=8, threshold=2.0)
    per_host = np.ones(4)
    for step in range(20):
        slow = step in (10, 13, 16)
        t = 1.0 if not slow else 5.0
        ph = per_host.copy()
        if slow:
            ph[2] = 5.0
        wd.record(step, t, per_host_seconds=ph)
    assert len(wd.events) == 3
    decision = wd.decide()
    assert decision == {"action": "evict_host", "host": 2,
                        "then": "elastic_restore"}


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_quantize_roundtrip_bound(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32)) * 10
    q, s = comp.quantize_int8(x)
    err = jnp.abs(comp.dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) * 0.5 + 1e-6


def test_error_feedback_unbiased_over_time():
    """EF carries the residual: the *sum* of transmitted values converges to
    the sum of true gradients (first-order unbiasedness)."""
    rng = np.random.default_rng(0)
    true = [jnp.asarray(rng.standard_normal(64).astype(np.float32))
            for _ in range(50)]
    err = {"g": jnp.zeros((64,))}
    sent_sum = jnp.zeros((64,))
    true_sum = jnp.zeros((64,))
    for g in true:
        (payload, err) = comp.ef_compress({"g": g}, err)
        q, s = payload["g"]
        sent_sum = sent_sum + comp.dequantize_int8(q, s)
        true_sum = true_sum + g
    # residual error is bounded by one quantization step, not O(T)
    assert float(jnp.abs(sent_sum - true_sum).max()) < 0.5


def test_compressed_psum_inside_shard_map():
    mesh = jax.make_mesh((1,), ("data",))
    grads = {"w": jnp.ones((8,)) * 0.37}
    err = comp.init_error(grads)

    def f(g, e):
        return comp.compressed_psum(g, e, "data")

    from jax.sharding import PartitionSpec as P

    out, new_err = jax.shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
    )(grads, err)
    np.testing.assert_allclose(np.asarray(out["w"]), 0.37, atol=0.01)
